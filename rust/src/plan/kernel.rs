//! Block-geometry autotuner (DESIGN.md §5.3): picks `[kernel]`
//! `block_rows`/`block_edges` for one workload by micro-benchmarking the
//! CSR row-blocked aggregation kernel itself — no analytic model, the
//! real `refexec::agg_csr` runs on a bounded prefix of the scenario
//! graph. Block geometry is scheduling, never numerics (every candidate
//! produces bit-identical panels — `rust/src/runtime/refexec.rs` tests
//! assert this), so the tuner only has to rank wall-clock, not re-verify
//! results.
//!
//! Invoked from `neutron-tp plan` when `[kernel] autotune = true`: the
//! tuned pair is pinned into the search base before candidate
//! enumeration, so the emitted winner TOML carries concrete numbers and
//! round-trips through the plan self-verify unchanged. Results are
//! memoized per `(profile, intra_threads, fast)` for the life of the
//! process — `neutron-tp plan` scores hundreds of candidates but tunes
//! once.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::graph::Csr;
use crate::runtime::executor::Arg;
use crate::runtime::refexec::{self, CsrCache, ExecCtx};

/// Row-block candidates around the library default (`BLOCK_ROWS`).
pub const ROWS_LATTICE: [usize; 4] = [64, 128, 256, 512];

/// Edge-block candidates around the library default (`BLOCK_EDGES`).
pub const EDGES_LATTICE: [usize; 3] = [8 * 1024, 32 * 1024, 128 * 1024];

/// Max destination rows sampled from the scenario graph for the
/// micro-bench: enough blocks to exercise every lattice point, small
/// enough that tuning stays well under a second per geometry.
const BENCH_ROW_CAP: usize = 8 * 1024;

/// Max edges sampled for the micro-bench (the prefix stops at whichever
/// cap it hits first).
const BENCH_EDGE_CAP: usize = 256 * 1024;

/// Feature panel width used by the micro-bench: one dim tile, the unit
/// every staged slice width is a multiple of.
const BENCH_COLS: usize = 32;

/// Timed repetitions per geometry; the best (min) is kept so scheduler
/// noise inflates no candidate.
const BENCH_REPS: usize = 3;

/// One tuned geometry, with the winning micro-bench time for reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelTuning {
    pub block_rows: usize,
    pub block_edges: usize,
    /// best-of-reps seconds for one aggregation pass over the sample
    pub micro_secs: f64,
}

impl KernelTuning {
    fn library_default() -> Self {
        KernelTuning {
            block_rows: refexec::BLOCK_ROWS,
            block_edges: refexec::BLOCK_EDGES,
            micro_secs: 0.0,
        }
    }
}

type TuneKey = (String, usize, bool);

fn tuned_cache() -> &'static Mutex<HashMap<TuneKey, KernelTuning>> {
    static CACHE: OnceLock<Mutex<HashMap<TuneKey, KernelTuning>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// The geometries a tuning run times, library default first. `fast`
/// keeps single-axis deviations from the default (the seed set, 7
/// points); a full run crosses the two lattices (13 points).
pub fn lattice(fast: bool) -> Vec<(usize, usize)> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    let mut push = |p: (usize, usize), out: &mut Vec<(usize, usize)>| {
        if !out.contains(&p) {
            out.push(p);
        }
    };
    push((refexec::BLOCK_ROWS, refexec::BLOCK_EDGES), &mut out);
    for &r in &ROWS_LATTICE {
        push((r, refexec::BLOCK_EDGES), &mut out);
    }
    for &e in &EDGES_LATTICE {
        push((refexec::BLOCK_ROWS, e), &mut out);
    }
    if !fast {
        for &r in &ROWS_LATTICE {
            for &e in &EDGES_LATTICE {
                push((r, e), &mut out);
            }
        }
    }
    out
}

/// Tune block geometry for `profile`'s graph at the given kernel team
/// width. Memoized per `(profile, intra_threads, fast)`; an edgeless
/// graph short-circuits to the library defaults.
pub fn autotune(profile: &str, g: &Csr, intra_threads: usize, fast: bool) -> KernelTuning {
    let key: TuneKey = (profile.to_string(), intra_threads, fast);
    if let Some(hit) = tuned_cache().lock().unwrap().get(&key) {
        return *hit;
    }
    let tuned = tune_uncached(g, intra_threads, fast);
    tuned_cache().lock().unwrap().insert(key, tuned);
    tuned
}

/// Build the `agg_pallas` argument set from a prefix of `g`: real
/// `row_ptr` segmentation (the degree profile is exactly what block
/// layout reacts to), columns folded into the sampled row range so the
/// synthetic panel stays small, deterministic synthetic features.
fn bench_args(g: &Csr) -> Option<(Vec<Arg>, usize)> {
    let rp = g.row_ptr();
    if g.num_edges() == 0 || rp.len() < 2 {
        return None;
    }
    let mut c = 0usize;
    while c + 1 < rp.len() && c < BENCH_ROW_CAP && (rp[c + 1] as usize) <= BENCH_EDGE_CAP {
        c += 1;
    }
    let c = c.max(1);
    let e = rp[c] as usize;
    if e == 0 {
        return None;
    }
    let row_ptr: Vec<i32> = rp[..=c].iter().map(|&v| v as i32).collect();
    let col: Vec<i32> = g.col()[..e].iter().map(|&v| (v as usize % c) as i32).collect();
    let ew: Vec<f32> = g.weights()[..e].to_vec();
    // the CSR path never reads edge_dst (that is the scatter oracle's
    // companion input); keep the arity the store expects
    let edge_dst = vec![0i32; e];
    let x: Vec<f32> =
        (0..c * BENCH_COLS).map(|i| (i % 97) as f32 * 0.031_25 - 1.5).collect();
    let args = vec![
        Arg::i32(row_ptr, &[c + 1]),
        Arg::i32(edge_dst, &[e]),
        Arg::i32(col, &[e]),
        Arg::f32(ew, &[e]),
        Arg::f32(x, &[c, BENCH_COLS]),
    ];
    Some((args, e))
}

fn tune_uncached(g: &Csr, intra_threads: usize, fast: bool) -> KernelTuning {
    let Some((args, _edges)) = bench_args(g) else {
        return KernelTuning::library_default();
    };
    let cache = CsrCache::new();
    let mut best = KernelTuning {
        block_rows: refexec::BLOCK_ROWS,
        block_edges: refexec::BLOCK_EDGES,
        micro_secs: f64::INFINITY,
    };
    for (block_rows, block_edges) in lattice(fast) {
        let ctx = ExecCtx {
            artifact: "kernel-autotune",
            intra_threads: intra_threads.max(1),
            block_rows,
            block_edges,
            cache: &cache,
        };
        // warm run: builds this geometry's memoized layout so block
        // segmentation cost stays out of the steady-state timing
        if refexec::execute_with("agg_pallas", &args, &ctx).is_err() {
            continue;
        }
        let mut secs = f64::INFINITY;
        for _ in 0..BENCH_REPS {
            let t0 = Instant::now();
            let _ = refexec::execute_with("agg_pallas", &args, &ctx);
            secs = secs.min(t0.elapsed().as_secs_f64());
        }
        if secs < best.micro_secs {
            best = KernelTuning { block_rows, block_edges, micro_secs: secs };
        }
    }
    if best.micro_secs.is_infinite() {
        return KernelTuning::library_default();
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn lattice_leads_with_default_and_fast_is_subset() {
        let fast = lattice(true);
        let full = lattice(false);
        assert_eq!(fast[0], (refexec::BLOCK_ROWS, refexec::BLOCK_EDGES));
        assert_eq!(fast.len(), 7);
        assert_eq!(full.len(), ROWS_LATTICE.len() * EDGES_LATTICE.len() + 1);
        assert!(fast.iter().all(|p| full.contains(p)), "fast set must be a subset");
        for set in [&fast, &full] {
            let mut seen = std::collections::HashSet::new();
            assert!(set.iter().all(|p| seen.insert(*p)), "no duplicate geometries");
        }
    }

    #[test]
    fn autotune_returns_a_lattice_member_and_memoizes() {
        let g = generate::rmat(512, 4096, (0.45, 0.2, 0.2, 0.15), 7).gcn_normalized();
        let first = autotune("kernel-tuner-test", &g, 2, true);
        assert!(
            lattice(true).contains(&(first.block_rows, first.block_edges)),
            "winner {}x{} must come from the searched lattice",
            first.block_rows,
            first.block_edges
        );
        assert!(first.micro_secs.is_finite() && first.micro_secs >= 0.0);
        // second call is a cache hit: identical result, including the
        // (otherwise non-reproducible) measured time
        let second = autotune("kernel-tuner-test", &g, 2, true);
        assert_eq!(first, second);
    }

    #[test]
    fn bench_args_sample_caps_and_folds_columns() {
        let g = generate::rmat(512, 4096, (0.45, 0.2, 0.2, 0.15), 3).gcn_normalized();
        let (args, edges) = bench_args(&g).expect("rmat graph has edges");
        assert!(edges <= BENCH_EDGE_CAP);
        assert_eq!(args.len(), 5);
        let (Arg::I32(rp, _), Arg::I32(col, _)) = (&args[0], &args[2]) else {
            panic!("row_ptr/col must be i32 args");
        };
        let c = rp.len() - 1;
        assert!(c <= BENCH_ROW_CAP);
        assert!(col.iter().all(|&v| (v as usize) < c), "columns folded into sampled rows");
    }

    #[test]
    fn edgeless_graph_falls_back_to_library_defaults() {
        let g = crate::graph::Csr::new(4, vec![0, 0, 0, 0, 0], vec![], vec![]);
        let t = autotune("kernel-tuner-empty", &g, 1, true);
        assert_eq!((t.block_rows, t.block_edges), (refexec::BLOCK_ROWS, refexec::BLOCK_EDGES));
    }
}
