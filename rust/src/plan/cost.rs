//! Cost model seams of the auto-planner (DESIGN.md §10.3): score one
//! candidate configuration **without running a training epoch** by
//! replaying its epoch schedule against a timing-mode [`Comm`] — the
//! collectives are posted exactly as the engines post them (the same
//! byte formulas `parallel::trace` mirrors), while the device compute
//! that a real epoch would *measure* is substituted with an analytic
//! estimate (edges·cols for aggregation, FLOPs for dense chains), fed
//! through the same `gpu_speedup` scaling the engines apply.
//!
//! Two entry points per candidate:
//!
//! * [`CostModel::score`] — the full replay: event-sim makespan with
//!   pipelined split pieces, host-staging stalls
//!   ([`StagingRun::ready_for_step`] on the real staging plan), per-layer
//!   DepComm, sequential broadcasts, and the gradient allreduce.
//! * [`CostModel::quick_bound`] — a *sound lower bound* on the full
//!   score's makespan (every per-worker stream in the event sim is
//!   serial, so the makespan is at least any worker's summed wire time
//!   and at least any worker's summed compute time), used by the search
//!   to discard dominated candidates before paying for a full replay.
//!   Soundness is lattice-tested in `rust/tests/plan.rs`.
//!
//! [`Defect`] seeds deliberate cost-model bugs for the mutation tests
//! (the `analysis.rs` style): each variant must be caught by a dedicated
//! assertion in `rust/tests/plan.rs`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::cluster::{Comm, CommHandle};
use crate::config::{AllReduceAlgo, ModelKind, RunConfig, System, Task};
use crate::graph::chunk::ChunkPlan;
use crate::graph::datasets::Profile;
use crate::graph::partition::{chunk_partition, greedy_min_cut};
use crate::graph::Csr;
use crate::model::layer_dims;
use crate::parallel::common;
use crate::runtime::memory::fullgraph_resident_bytes;
use crate::runtime::{ArtifactStore, DeviceMemory};
use crate::sched::chunks::ChunkGeometry;
use crate::sched::{PipelinePlan, StagingPlan, StagingRun, StagingSpec};
use crate::tensor::{dim_slices, pad_tile, row_slices};

// ---- analytic compute constants (measured-scale seconds, i.e. before
// the `gpu_speedup` division `common::modeled` applies) ----------------

/// Seconds per (edge × column) of CSR aggregation at one kernel thread.
const AGG_SECS_PER_EDGE_COL: f64 = 1.0e-9;
/// Seconds per dense FLOP (matmul counts 2·m·k·n).
const DENSE_SECS_PER_FLOP: f64 = 5.0e-10;
/// Fixed dispatch overhead per submitted artifact job.
const JOB_OVERHEAD_SECS: f64 = 40.0e-6;
/// Extra spawn cost per additional intra-job kernel thread.
const TEAM_SPAWN_SECS: f64 = 15.0e-6;
/// Amdahl parallel fraction of the row-blocked aggregation kernel.
const AMDAHL_PARALLEL_FRAC: f64 = 0.85;

/// Amdahl speedup factor of an aggregation kernel run with `intra`
/// team threads (1.0 at one thread; floor of 0.15 serial share).
fn team_factor(intra: usize) -> f64 {
    let t = intra.max(1) as f64;
    (1.0 - AMDAHL_PARALLEL_FRAC) + AMDAHL_PARALLEL_FRAC / t
}

/// Per-job dispatch cost: fixed overhead plus team spawn.
fn job_cost(intra: usize) -> f64 {
    JOB_OVERHEAD_SECS + TEAM_SPAWN_SECS * (intra.max(1) as f64 - 1.0)
}

/// One candidate's modeled cost: event-sim epoch makespan and the peak
/// device-memory requirement its memory plan commits to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Score {
    pub makespan_secs: f64,
    pub peak_mem_bytes: usize,
}

/// Deliberate cost-model mutations for the planner's mutation-test
/// matrix (`rust/tests/plan.rs`): each variant models a realistic
/// cost-model bug, and a dedicated test must fail it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Defect {
    #[default]
    None,
    /// drop the gradient-allreduce collective from the replay (a
    /// "forgot a comm term" bug) — caught by byte conservation against
    /// `trace::record_comm_schedule`
    DropAllreduceTerm,
    /// ignore `[comm] bw_scale` (plan as if every NIC were equal) —
    /// caught by straggler topologies scoring no worse than homogeneous
    IgnoreTopologySkew,
    /// treat host-staging PCIe traffic as free (skip the staging
    /// replay) — caught by tight-memory budgets scoring no worse than
    /// roomy ones
    FreeStagingStalls,
    /// inflate the quick bound ×2 (an unsound pruning bound) — caught
    /// by the lattice invariant `quick_bound ≤ score`
    InflatedQuickBound,
}

/// Per-worker derived quantities of the data-parallel contiguous
/// partition (`chunk_partition`) the DepComm/DepCache engines use.
struct DpPart {
    /// remote source vertices each worker must fetch per layer
    remote: Vec<usize>,
    /// edges into each worker's own rows (its aggregation work)
    own_edges: Vec<usize>,
    /// edges into each worker's *remote* sources (DepCache's redundant
    /// halo aggregation)
    halo_edges: Vec<usize>,
}

/// Per-worker derived quantities of the `greedy_min_cut` partition the
/// historical-embedding baseline broadcasts over.
struct HistPart {
    member_counts: Vec<usize>,
    member_edges: Vec<usize>,
}

/// The planner's cost model over one `(profile, graph, artifact store)`
/// scenario. Graph-derived structures (chunk plans, partitions) are
/// memoized across the hundreds of candidates one search scores.
pub struct CostModel<'a> {
    p: Profile,
    g: &'a Csr,
    store: &'a ArtifactStore,
    defect: Defect,
    plans: RefCell<HashMap<(usize, usize, usize), Rc<ChunkPlan>>>,
    dp_parts: RefCell<HashMap<usize, Rc<DpPart>>>,
    hist_parts: RefCell<HashMap<usize, Rc<HistPart>>>,
}

impl<'a> CostModel<'a> {
    pub fn new(store: &'a ArtifactStore, p: Profile, g: &'a Csr) -> Self {
        CostModel {
            p,
            g,
            store,
            defect: Defect::None,
            plans: RefCell::new(HashMap::new()),
            dp_parts: RefCell::new(HashMap::new()),
            hist_parts: RefCell::new(HashMap::new()),
        }
    }

    /// Seed a deliberate cost-model bug (mutation tests only).
    pub fn with_defect(mut self, defect: Defect) -> Self {
        self.defect = defect;
        self
    }

    // ---- full replay -------------------------------------------------

    /// Full event-sim score of one candidate. `Err` means the candidate
    /// is infeasible for this scenario (its message contains "OOM" when
    /// the memory plan is the reason).
    pub fn score(&self, cfg: &RunConfig) -> crate::Result<Score> {
        let peak_mem_bytes = self.peak_mem(&self.effective(cfg))?;
        let comm = self.replay_comm(cfg)?;
        Ok(Score { makespan_secs: comm.makespan(), peak_mem_bytes })
    }

    /// Run the full replay and hand back its communicator — the byte
    /// conservation tests compare its per-kind [`crate::cluster::CommStats`]
    /// against `parallel::trace::record_comm_schedule`'s.
    pub fn replay_comm(&self, cfg: &RunConfig) -> crate::Result<Comm> {
        let cfg = self.effective(cfg);
        match cfg.system {
            System::NeutronTp => self.replay_tp(&cfg, true),
            System::NaiveTp => self.replay_tp(&cfg, false),
            System::DpFull => self.replay_dp(&cfg, false),
            System::DpCache => self.replay_dp(&cfg, true),
            System::Historical => self.replay_historical(&cfg),
            System::MiniBatch => anyhow::bail!(
                "mini_batch is outside the planner's search space \
                 (sampling changes convergence semantics, DESIGN.md §10.2)"
            ),
        }
    }

    /// Replay a TP epoch's schedule (decoupled = NeutronTP, else naive
    /// TP) against a timing-mode communicator — the mirror of
    /// `TpEngine::epoch_decoupled` / `epoch_naive` with analytic compute.
    fn replay_tp(&self, cfg: &RunConfig, decoupled: bool) -> crate::Result<Comm> {
        anyhow::ensure!(
            decoupled || cfg.model == ModelKind::Gcn,
            "naive TP supports GCN only"
        );
        let n = cfg.workers;
        let v = self.p.v;
        let lp = cfg.task == Task::LinkPrediction;
        let dims = layer_dims(&self.p, cfg.layers, cfg.feat_dim, lp);
        let l = cfg.layers;
        let row_parts = row_slices(v, n);
        let memplan = common::memplan_for(cfg, &self.p, self.g, self.store, &dims, decoupled)?;
        let plan = self.chunk_plan(&memplan.geometry);
        let mut comm = Comm::for_run(cfg)?;

        if decoupled {
            let wf = *dims.last().unwrap();
            let dim_parts = dim_slices(wf, n);

            // phase 1: NN chains on vertex slices, from t=0
            let nn_fwd = self.nn_secs(cfg, &dims, v, 1.0);
            for (w, part) in row_parts.iter().enumerate() {
                let share = part.len() as f64 / v.max(1) as f64;
                comm.compute(w, common::modeled(cfg, nn_fwd * share), 0.0);
            }

            if cfg.model == ModelKind::Gat {
                // attention prologue (TpEngine + trace.rs byte formulas)
                let attn = self.dense_secs(4 * v * wf, common::CANON_DATA_PARTS);
                for (w, part) in row_parts.iter().enumerate() {
                    let share = part.len() as f64 / v.max(1) as f64;
                    comm.compute(w, common::modeled(cfg, attn * share), 0.0);
                }
                let block_bytes: Vec<usize> =
                    row_parts.iter().map(|r| r.len() * 4).collect();
                let _ = comm.iallgather_bytes(&block_bytes).wait();
                for (ci, c) in plan.chunks.iter().enumerate() {
                    let secs = self.agg_secs(cfg, c.live_edges, 1) + job_cost(cfg.intra_threads);
                    comm.compute(ci % n, common::modeled(cfg, secs), 0.0);
                }
                let alpha_bytes = self.g.num_edges() * 4;
                for w in 0..n {
                    comm.p2p_wire(w, alpha_bytes * (n - 1) / n.max(1));
                }
            }
            comm.barrier();

            // phases 2..4: split -> L aggregation rounds -> gather
            self.agg_phase_cost(
                cfg, &mut comm, &plan, memplan.staging.as_ref(), wf, l, &row_parts, &dim_parts,
            )?;
            let agg_fwd_done: Vec<f64> = (0..n).map(|w| comm.now(w)).collect();

            // phase 5: downstream task
            match cfg.task {
                Task::NodeClassification => {
                    let t = self.loss_secs(v, wf);
                    for (w, part) in row_parts.iter().enumerate() {
                        let share = part.len() as f64 / v.max(1) as f64;
                        comm.compute(w, common::modeled(cfg, t * share), agg_fwd_done[w]);
                    }
                }
                Task::LinkPrediction => {
                    let parts = common::CANON_DATA_PARTS;
                    let pairs = (cfg.batch_size / parts).max(8);
                    let fetch_total = parts * pairs * wf * 4 * 2;
                    for w in 0..n {
                        comm.p2p(w, fetch_total / n.max(1));
                    }
                    let t = self.dense_secs(2 * parts * pairs * wf * 4, parts);
                    for w in 0..n {
                        let now = comm.now(w);
                        comm.compute(w, common::modeled(cfg, t / n.max(1) as f64), now);
                    }
                }
            }
            comm.barrier();

            // backward: split -> L transposed rounds -> gather (the
            // transpose shares chunk-row geometry and edge totals, so the
            // forward plan stands in for it — exactly as trace.rs does)
            self.agg_phase_cost(
                cfg, &mut comm, &plan, memplan.staging.as_ref(), wf, l, &row_parts, &dim_parts,
            )?;

            // NN backward
            let nn_bwd = self.nn_secs(cfg, &dims, v, 2.0);
            for (w, part) in row_parts.iter().enumerate() {
                let share = part.len() as f64 / v.max(1) as f64;
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, nn_bwd * share), now);
            }
            comm.barrier();
        } else {
            // naive TP: coupled aggregate-then-update per layer
            for li in 0..l {
                let dp = dim_slices(dims[li], n);
                self.agg_phase_cost(
                    cfg, &mut comm, &plan, None, dims[li], 1, &row_parts, &dp,
                )?;
                for (w, part) in row_parts.iter().enumerate() {
                    let secs = self.dense_secs(2 * part.len() * dims[li] * dims[li + 1], 1);
                    let now = comm.now(w);
                    comm.compute(w, common::modeled(cfg, secs), now);
                }
                comm.barrier();
            }
            let t = self.loss_secs(v, dims[l]);
            for (w, part) in row_parts.iter().enumerate() {
                let share = part.len() as f64 / v.max(1) as f64;
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, t * share), now);
            }
            comm.barrier();
            for li in (0..l).rev() {
                for (w, part) in row_parts.iter().enumerate() {
                    let secs =
                        self.dense_secs(4 * part.len() * dims[li] * dims[li + 1], 1);
                    let now = comm.now(w);
                    comm.compute(w, common::modeled(cfg, secs), now);
                }
                comm.barrier();
                let dp = dim_slices(dims[li], n);
                self.agg_phase_cost(
                    cfg, &mut comm, &plan, None, dims[li], 1, &row_parts, &dp,
                )?;
            }
        }

        self.allreduce_cost(cfg, &mut comm, &dims);
        comm.barrier();
        Ok(comm)
    }

    /// The TP aggregation phase's schedule: one split, `rounds` compute
    /// rounds, one gather — pipelined chunk pieces and host-staging
    /// ready-times included, mirroring `TpEngine::agg_phase`.
    #[allow(clippy::too_many_arguments)]
    fn agg_phase_cost(
        &self,
        cfg: &RunConfig,
        comm: &mut Comm,
        plan: &ChunkPlan,
        staging_spec: Option<&StagingSpec>,
        wf: usize,
        rounds: usize,
        row_parts: &[std::ops::Range<usize>],
        dim_parts: &[std::ops::Range<usize>],
    ) -> crate::Result<()> {
        let n = row_parts.len();
        let v = plan.num_vertices;
        let slice_w = dim_parts[0].len().max(1);
        let num_chunks = plan.num_chunks();
        let pipelined = cfg.pipeline && num_chunks > 1;
        // under FreeStagingStalls the run is never constructed: its
        // replay contract (every step visited, then finish) would
        // otherwise debug-assert
        let mut staging = match staging_spec {
            Some(spec) if self.defect != Defect::FreeStagingStalls => Some(
                StagingRun::new(spec, &plan.chunks, slice_w, rounds, pipelined)?,
            ),
            _ => None,
        };

        if pipelined {
            let pplan = PipelinePlan::build(&plan.chunks, slice_w, n, v);
            let mut split_handles: Vec<Option<CommHandle<()>>> =
                comm.isplit_pieces(&pplan.split_bytes).into_iter().map(Some).collect();
            let mut gather_handles: Vec<CommHandle<()>> = Vec::with_capacity(num_chunks);
            for r in 0..rounds {
                for ci in 0..num_chunks {
                    let secs = self.agg_secs(cfg, plan.chunks[ci].live_edges, wf)
                        + job_cost(cfg.intra_threads);
                    let total = common::modeled(cfg, secs);
                    let mut ready = match split_handles.get_mut(ci).and_then(Option::take) {
                        Some(handle) if r == 0 => handle.wait_barrier().1,
                        _ => 0.0,
                    };
                    if let Some(st) = staging.as_mut() {
                        let t = (0..n).map(|w| comm.now(w)).fold(ready, f64::max);
                        ready = ready.max(st.ready_for_step(r * num_chunks + ci, t)?);
                    }
                    for w in 0..n {
                        let frac = dim_parts[w].len() as f64 / wf.max(1) as f64;
                        comm.compute(w, total * frac, ready);
                    }
                    if r + 1 == rounds {
                        let bytes = pplan.gather_bytes.get(ci).copied().unwrap_or(0);
                        gather_handles.push(comm.igather_piece(bytes));
                    }
                }
            }
            for handle in gather_handles {
                let _ = handle.wait();
            }
        } else {
            let _ = comm.isplit_bytes(row_parts, dim_parts).wait();
            comm.barrier();
            let phase_secs: f64 = plan
                .chunks
                .iter()
                .map(|c| self.agg_secs(cfg, c.live_edges, wf) + job_cost(cfg.intra_threads))
                .sum();
            for r in 0..rounds {
                let total = common::modeled(cfg, phase_secs);
                let mut swap_ready = 0.0;
                if let Some(st) = staging.as_mut() {
                    let t = (0..n).map(|w| comm.now(w)).fold(0.0, f64::max);
                    swap_ready = st.ready_for_round(r, num_chunks, t)?;
                }
                for w in 0..n {
                    let frac = dim_parts[w].len() as f64 / wf.max(1) as f64;
                    let now = comm.now(w).max(swap_ready);
                    comm.compute(w, total * frac, now);
                }
            }
            let _ = comm.igather_bytes(row_parts, dim_parts).wait();
            comm.barrier();
        }
        if let Some(st) = staging {
            let _ = st.finish();
        }
        Ok(())
    }

    /// Replay a data-parallel epoch (DepComm when `cache` is false,
    /// DepCache when true) — the mirror of `DpEngine`'s schedule.
    fn replay_dp(&self, cfg: &RunConfig, cache: bool) -> crate::Result<Comm> {
        anyhow::ensure!(cfg.model == ModelKind::Gcn, "DP baselines support GCN only");
        let n = cfg.workers;
        let v = self.p.v;
        let dims = layer_dims(&self.p, cfg.layers, cfg.feat_dim, false);
        let l = cfg.layers;
        let row_parts = row_slices(v, n);
        let pi = self.dp_part(n);
        let mut comm = Comm::for_run(cfg)?;

        if cache {
            // one-time halo feature replication
            for w in 0..n {
                comm.p2p(w, pi.remote[w] * dims[0] * 4);
            }
        }
        for li in 0..l {
            if !cache {
                for w in 0..n {
                    comm.p2p(w, pi.remote[w] * dims[li] * 4);
                }
                comm.barrier();
            }
            for w in 0..n {
                let secs =
                    self.agg_secs(cfg, pi.own_edges[w], dims[li]) + job_cost(cfg.intra_threads);
                let m = common::modeled(cfg, secs);
                let now = comm.now(w);
                comm.compute(w, m, now);
                if cache {
                    let ratio = pi.halo_edges[w] as f64 / pi.own_edges[w].max(1) as f64;
                    let now = comm.now(w);
                    comm.compute(w, m * ratio, now);
                }
            }
            comm.barrier();
            for (w, part) in row_parts.iter().enumerate() {
                let secs = self.dense_secs(2 * part.len() * dims[li] * dims[li + 1], 1);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
        }

        let t = self.loss_secs(v, dims[l]);
        for (w, part) in row_parts.iter().enumerate() {
            let share = part.len() as f64 / v.max(1) as f64;
            let now = comm.now(w);
            comm.compute(w, common::modeled(cfg, t * share), now);
        }
        comm.barrier();

        for li in (0..l).rev() {
            for (w, part) in row_parts.iter().enumerate() {
                let secs = self.dense_secs(4 * part.len() * dims[li] * dims[li + 1], 1);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
            if !cache {
                for w in 0..n {
                    comm.p2p(w, pi.remote[w] * dims[li] * 4);
                }
                comm.barrier();
            }
            for w in 0..n {
                let secs =
                    self.agg_secs(cfg, pi.own_edges[w], dims[li]) + job_cost(cfg.intra_threads);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
        }

        self.allreduce_cost(cfg, &mut comm, &dims);
        comm.barrier();
        Ok(comm)
    }

    /// Replay the historical-embedding baseline at its refresh epoch
    /// (epoch 0 always refreshes — the planner scores the expensive
    /// epoch, not the stale-cache one).
    fn replay_historical(&self, cfg: &RunConfig) -> crate::Result<Comm> {
        anyhow::ensure!(
            cfg.model == ModelKind::Gcn,
            "the historical baseline supports GCN only"
        );
        let n = cfg.workers;
        let v = self.p.v;
        let dims = layer_dims(&self.p, cfg.layers, cfg.feat_dim, false);
        let l = cfg.layers;
        let row_parts = row_slices(v, n);
        let pi = self.hist_part(n);
        let mut comm = Comm::for_run(cfg)?;

        for li in 0..l {
            let bw: Vec<usize> =
                pi.member_counts.iter().map(|c| c * dims[li] * 4).collect();
            let _ = comm.isequential_broadcast_bytes(&bw).wait();
            comm.barrier();
            for w in 0..n {
                let secs =
                    self.agg_secs(cfg, pi.member_edges[w], dims[li]) + job_cost(cfg.intra_threads);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
            for (w, part) in row_parts.iter().enumerate() {
                let secs = self.dense_secs(2 * part.len() * dims[li] * dims[li + 1], 1);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
        }

        let t = self.loss_secs(v, dims[l]);
        for (w, part) in row_parts.iter().enumerate() {
            let share = part.len() as f64 / v.max(1) as f64;
            let now = comm.now(w);
            comm.compute(w, common::modeled(cfg, t * share), now);
        }
        comm.barrier();

        for li in (0..l).rev() {
            for (w, part) in row_parts.iter().enumerate() {
                let secs = self.dense_secs(4 * part.len() * dims[li] * dims[li + 1], 1);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
            let bw: Vec<usize> =
                pi.member_counts.iter().map(|c| c * dims[li] * 4).collect();
            let _ = comm.isequential_broadcast_bytes(&bw).wait();
            for w in 0..n {
                let secs =
                    self.agg_secs(cfg, pi.member_edges[w], dims[li]) + job_cost(cfg.intra_threads);
                let now = comm.now(w);
                comm.compute(w, common::modeled(cfg, secs), now);
            }
            comm.barrier();
        }

        self.allreduce_cost(cfg, &mut comm, &dims);
        comm.barrier();
        Ok(comm)
    }

    /// The per-epoch gradient allreduce (volume per `trace.rs`), unless
    /// the `DropAllreduceTerm` mutation is seeded.
    fn allreduce_cost(&self, cfg: &RunConfig, comm: &mut Comm, dims: &[usize]) {
        if cfg.workers <= 1 || self.defect == Defect::DropAllreduceTerm {
            return;
        }
        let param_bytes: usize = dims.windows(2).map(|w| (w[0] * w[1] + w[1]) * 4).sum();
        let _ = comm.iallreduce_bytes(param_bytes).wait();
    }

    // ---- quick (pruning) bound ---------------------------------------

    /// Sound lower bound on [`CostModel::score`]'s makespan, sharing its
    /// peak-memory derivation. Every term below is ≤ the duration the
    /// full replay charges the same worker's (serial) comm or compute
    /// stream, and terms the replay adds on top (latency, barriers,
    /// dispatch overhead, staging stalls, GAT/LP extras) are simply
    /// omitted — omission only loosens a lower bound.
    pub fn quick_bound(&self, cfg: &RunConfig) -> crate::Result<Score> {
        let cfg = self.effective(cfg);
        let peak_mem_bytes = self.peak_mem(&cfg)?;
        let n = cfg.workers;
        let v = self.p.v;
        let lp = cfg.task == Task::LinkPrediction;
        let dims = layer_dims(&self.p, cfg.layers, cfg.feat_dim, lp);
        let l = cfg.layers;

        // wire-only seconds for worker `w` to move `bytes`, with the
        // topology's per-NIC scale applied exactly as `cluster::Comm`
        // applies it (≤ every msg_secs the sim would charge)
        let wire = |w: usize, bytes: usize| -> f64 {
            let scale = cfg.comm.bw_scale.get(w).copied().unwrap_or(1.0).max(1e-9);
            cfg.net.wire_secs(bytes) / scale
        };

        let mut comp = vec![0.0f64; n];
        let mut wire_lb = vec![0.0f64; n];

        match cfg.system {
            System::NeutronTp | System::NaiveTp => {
                let decoupled = cfg.system == System::NeutronTp;
                let memplan =
                    common::memplan_for(&cfg, &self.p, self.g, self.store, &dims, decoupled)?;
                let pipelined = cfg.pipeline && memplan.geometry.num_chunks > 1;
                // (phase width, rounds) pairs: decoupled runs two phases
                // at the final width; naive one per layer per direction
                let phases: Vec<(usize, usize)> = if decoupled {
                    let wf = *dims.last().unwrap();
                    vec![(wf, l), (wf, l)]
                } else {
                    let mut ps: Vec<(usize, usize)> =
                        (0..l).map(|li| (dims[li], 1)).collect();
                    ps.extend((0..l).rev().map(|li| (dims[li], 1)));
                    ps
                };
                let e = self.g.num_edges();
                for &(width, rounds) in &phases {
                    let dim_parts = dim_slices(width, n);
                    let slice_w = dim_parts[0].len().max(1);
                    for w in 0..n {
                        let frac = dim_parts[w].len() as f64 / width.max(1) as f64;
                        comp[w] += common::modeled(
                            &cfg,
                            self.agg_secs(&cfg, e, width) * rounds as f64 * frac,
                        );
                    }
                    if pipelined {
                        // every worker's NIC carries every chunk piece
                        let plan = self.chunk_plan(&memplan.geometry);
                        let pplan = PipelinePlan::build(&plan.chunks, slice_w, n, v);
                        let bytes: usize = pplan.split_bytes.iter().sum::<usize>()
                            + pplan.gather_bytes.iter().sum::<usize>();
                        for (w, t) in wire_lb.iter_mut().enumerate() {
                            *t += wire(w, bytes);
                        }
                    } else {
                        let row_parts = row_slices(v, n);
                        for (w, t) in wire_lb.iter_mut().enumerate() {
                            let dw = dim_parts[w].len();
                            let rw = row_parts[w].len();
                            let split_recv = (v - rw) * dw * 4;
                            let gather_recv = rw * (width - dw) * 4;
                            *t += wire(w, split_recv + gather_recv);
                        }
                    }
                }
            }
            System::DpFull | System::DpCache => {
                let cache = cfg.system == System::DpCache;
                let pi = self.dp_part(n);
                if cache {
                    for (w, t) in wire_lb.iter_mut().enumerate() {
                        *t += wire(w, pi.remote[w] * dims[0] * 4);
                    }
                }
                for li in 0..l {
                    for w in 0..n {
                        let mut secs = 2.0 * self.agg_secs(&cfg, pi.own_edges[w], dims[li]);
                        if cache {
                            secs += 2.0
                                * self.agg_secs(&cfg, pi.own_edges[w], dims[li])
                                * (pi.halo_edges[w] as f64 / pi.own_edges[w].max(1) as f64);
                        } else {
                            wire_lb[w] += 2.0 * wire(w, pi.remote[w] * dims[li] * 4);
                        }
                        comp[w] += common::modeled(&cfg, secs);
                    }
                }
            }
            System::Historical => {
                let pi = self.hist_part(n);
                for li in 0..l {
                    for w in 0..n {
                        comp[w] += common::modeled(
                            &cfg,
                            2.0 * self.agg_secs(&cfg, pi.member_edges[w], dims[li]),
                        );
                        // receive every other worker's block + wire own
                        // block to the n-1 peers, twice (fwd + bwd)
                        let recv: usize = pi
                            .member_counts
                            .iter()
                            .enumerate()
                            .filter(|(s, _)| *s != w)
                            .map(|(_, c)| c * dims[li] * 4)
                            .sum();
                        let sent = pi.member_counts[w] * dims[li] * 4 * (n - 1);
                        wire_lb[w] += 2.0 * wire(w, recv + sent);
                    }
                }
            }
            System::MiniBatch => {
                anyhow::bail!("mini_batch is outside the planner's search space")
            }
        }

        // gradient allreduce (skipped consistently with the full replay
        // when the DropAllreduceTerm mutation is seeded)
        if n > 1 && self.defect != Defect::DropAllreduceTerm {
            let pb: usize = dims.windows(2).map(|w| (w[0] * w[1] + w[1]) * 4).sum();
            match cfg.comm.allreduce {
                AllReduceAlgo::Ring => {
                    let share = 2.0 * (n - 1) as f64 / n as f64;
                    for (w, t) in wire_lb.iter_mut().enumerate() {
                        *t += share * wire(w, pb);
                    }
                }
                AllReduceAlgo::FlatTree => {
                    for (w, t) in wire_lb.iter_mut().enumerate() {
                        *t += if w == 0 {
                            2.0 * (n - 1) as f64 * wire(0, pb)
                        } else {
                            wire(w, pb)
                        };
                    }
                }
            }
        }

        let mut lb = 0.0f64;
        for w in 0..n {
            lb = lb.max(comp[w]).max(wire_lb[w]);
        }
        if self.defect == Defect::InflatedQuickBound {
            lb *= 2.0;
        }
        Ok(Score { makespan_secs: lb, peak_mem_bytes })
    }

    // ---- shared derivations ------------------------------------------

    /// Apply model-level mutations that act on the candidate itself.
    fn effective(&self, cfg: &RunConfig) -> RunConfig {
        let mut c = cfg.clone();
        if self.defect == Defect::IgnoreTopologySkew {
            c.comm.bw_scale.clear();
        }
        c
    }

    /// Peak device-memory requirement of the candidate's memory plan —
    /// the second dominance axis. `Err` (containing "OOM") marks the
    /// candidate infeasible, mirroring each engine's own gate.
    fn peak_mem(&self, cfg: &RunConfig) -> crate::Result<usize> {
        let lp = cfg.task == Task::LinkPrediction;
        let dims = layer_dims(&self.p, cfg.layers, cfg.feat_dim, lp);
        let mem = DeviceMemory::from_mb(cfg.device_mem_mb);
        match cfg.system {
            System::NeutronTp | System::NaiveTp => {
                let decoupled = cfg.system == System::NeutronTp;
                let memplan =
                    common::memplan_for(cfg, &self.p, self.g, self.store, &dims, decoupled)?;
                match &memplan.staging {
                    Some(spec) => {
                        let plan = self.chunk_plan(&memplan.geometry);
                        let wf = *dims.last().unwrap();
                        let slice_w = dim_slices(wf, cfg.workers)[0].len().max(1);
                        let sp = StagingPlan::build(spec, &plan.chunks, slice_w, cfg.layers)?;
                        Ok(sp.planned_peak)
                    }
                    None => {
                        let widest = *dims.iter().max().unwrap();
                        Ok((self.p.v / cfg.workers) * dims.iter().sum::<usize>() * 4
                            + self.p.v * pad_tile(widest.div_ceil(cfg.workers)) * 4)
                    }
                }
            }
            System::DpFull | System::DpCache => {
                let hidden = dims[1..].iter().copied().max().unwrap_or(1);
                let need = fullgraph_resident_bytes(
                    self.p.v / cfg.workers,
                    self.p.e / cfg.workers,
                    dims[0],
                    hidden,
                    cfg.layers,
                    1.0,
                );
                anyhow::ensure!(
                    mem.fits(need),
                    "modeled device OOM: {} needs {} MiB resident, budget {} MiB",
                    cfg.system.name(),
                    need >> 20,
                    cfg.device_mem_mb
                );
                Ok(need)
            }
            System::Historical => {
                let hidden = dims[1..].iter().copied().max().unwrap_or(1);
                let need = fullgraph_resident_bytes(
                    self.p.v,
                    self.p.e / cfg.workers,
                    dims[0],
                    hidden,
                    cfg.layers,
                    1.0,
                );
                anyhow::ensure!(
                    mem.fits(need),
                    "modeled device OOM: historical needs {} MiB resident, budget {} MiB",
                    need >> 20,
                    cfg.device_mem_mb
                );
                Ok(need)
            }
            System::MiniBatch => {
                anyhow::bail!("mini_batch is outside the planner's search space")
            }
        }
    }

    /// Analytic aggregation seconds (measured scale): edges × columns at
    /// the candidate's kernel team width. The team only engages on the
    /// block-parallel pallas lowering; the scatter baseline is serial.
    fn agg_secs(&self, cfg: &RunConfig, edges: usize, cols: usize) -> f64 {
        let team = match cfg.agg_impl {
            crate::config::AggImpl::Pallas => team_factor(cfg.intra_threads),
            crate::config::AggImpl::Scatter => 1.0,
        };
        edges as f64 * cols.max(1) as f64 * AGG_SECS_PER_EDGE_COL * team
    }

    /// Analytic dense seconds (measured scale) for `flops` FLOPs across
    /// `jobs` dispatches.
    fn dense_secs(&self, flops: usize, jobs: usize) -> f64 {
        flops as f64 * DENSE_SECS_PER_FLOP + jobs as f64 * JOB_OVERHEAD_SECS
    }

    /// Full NN chain over all `v` rows (`scale` = 1 forward, 2 backward).
    fn nn_secs(&self, cfg: &RunConfig, dims: &[usize], v: usize, scale: f64) -> f64 {
        let flops: usize = dims.windows(2).map(|w| 2 * v * w[0] * w[1]).sum();
        let jobs = if cfg.fused_nn {
            common::CANON_DATA_PARTS
        } else {
            common::CANON_DATA_PARTS * (dims.len() - 1)
        };
        flops as f64 * scale * DENSE_SECS_PER_FLOP + jobs as f64 * job_cost(1)
    }

    /// Loss + gradient over `[v, k]` logits (softmax/xent-scale work).
    fn loss_secs(&self, v: usize, k: usize) -> f64 {
        self.dense_secs(4 * v * k, common::CANON_DATA_PARTS)
    }

    fn chunk_plan(&self, geo: &ChunkGeometry) -> Rc<ChunkPlan> {
        let key = (geo.rows_per_chunk, geo.c_bucket, geo.e_bucket);
        if let Some(p) = self.plans.borrow().get(&key) {
            return p.clone();
        }
        let plan = Rc::new(ChunkPlan::build(
            self.g,
            geo.rows_per_chunk,
            geo.c_bucket,
            geo.e_bucket,
        ));
        self.plans.borrow_mut().insert(key, plan.clone());
        plan
    }

    fn dp_part(&self, n: usize) -> Rc<DpPart> {
        if let Some(p) = self.dp_parts.borrow().get(&n) {
            return p.clone();
        }
        let part = chunk_partition(self.p.v, n);
        let mut remote = Vec::with_capacity(n);
        let mut own_edges = Vec::with_capacity(n);
        let mut halo_edges = Vec::with_capacity(n);
        for w in 0..n {
            let rs = part.remote_srcs(self.g, w);
            halo_edges.push(rs.iter().map(|&s| self.g.in_deg(s as usize)).sum());
            remote.push(rs.len());
            own_edges
                .push(part.members(w).iter().map(|&m| self.g.in_deg(m as usize)).sum());
        }
        let pi = Rc::new(DpPart { remote, own_edges, halo_edges });
        self.dp_parts.borrow_mut().insert(n, pi.clone());
        pi
    }

    fn hist_part(&self, n: usize) -> Rc<HistPart> {
        if let Some(p) = self.hist_parts.borrow().get(&n) {
            return p.clone();
        }
        let part = greedy_min_cut(self.g, n);
        let mut member_counts = Vec::with_capacity(n);
        let mut member_edges = Vec::with_capacity(n);
        for w in 0..n {
            let ms = part.members(w);
            member_edges.push(ms.iter().map(|&m| self.g.in_deg(m as usize)).sum());
            member_counts.push(ms.len());
        }
        let pi = Rc::new(HistPart { member_counts, member_edges });
        self.hist_parts.borrow_mut().insert(n, pi.clone());
        pi
    }
}
