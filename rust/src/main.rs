//! neutron-tp CLI — the L3 leader entrypoint.
//!
//! ```text
//! neutron-tp train  [--config run.toml] [--profile rdt] [--system tp]
//!                   [--checkpoint-dir D [--resume]] ...
//! neutron-tp serve  [--checkpoint F | --profile P [--warm-epochs K]]
//!                   [--requests N] [--batch-size B]
//! neutron-tp check  [--all-profiles | same flags as train]
//! neutron-tp audit  [--all-profiles | same flags as train]
//! neutron-tp plan   [workload flags as train] [--emit plan.toml] [--fast]
//! neutron-tp bench  <fig3|fig4|...|serve_scale|all> [--out results/] [--fast]
//! neutron-tp inspect [--artifacts artifacts/]
//! ```
//!
//! (Hand-rolled arg parsing: the offline build has no clap.)

use std::str::FromStr;

use neutron_tp::analysis;
use neutron_tp::bench_harness::experiments;
use neutron_tp::config::RunConfig;
use neutron_tp::graph::datasets::{self, Dataset};
use neutron_tp::parallel::{self, Ctx};
use neutron_tp::runtime::{ArtifactStore, ExecutorPool};
use neutron_tp::serve::{self, checkpoint};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(flags: &Flags) -> String {
    flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string())
}

fn run() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..]);
    match cmd.as_str() {
        "train" => train(&flags),
        "serve" => serve_cmd(&flags),
        "check" => check_cmd(&flags),
        "audit" => audit_cmd(&flags),
        "plan" => plan_cmd(&flags),
        "bench" => bench(&args[1..], &flags),
        "inspect" => inspect(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            anyhow::bail!(
                "unknown command '{other}' (try: train, serve, check, audit, plan, bench, inspect)"
            )
        }
    }
}

fn print_usage() {
    println!(
        "neutron-tp — NeutronTP (PVLDB'24) reproduction\n\n\
         USAGE:\n  neutron-tp train [--config F] [--profile P] [--system S] [--model M]\n\
         \x20                  [--workers N] [--layers L] [--epochs E] [--lr X]\n\
         \x20                  [--agg-impl scatter|pallas] [--no-pipeline] [--no-chunk-sched]\n\
         \x20                  [--executor-threads N] [--intra-threads N] [--no-fused-nn]\n\
         \x20                  [--chunks C] [--device-mem-mb MB] [--feat-dim D] [--task nc|lp]\n\
         \x20                  [--pcie-gbps G] [--prefetch-depth K] [--no-swap]\n\
         \x20                  [--comm-all-to-all naive|pairwise] [--comm-allreduce ring|flat_tree]\n\
         \x20                  [--bw-scale S0,S1,...] [--bf16-wire] [--checkpoint-dir D] [--resume]\n\
         \x20                  [--block-rows R] [--block-edges E] [--kernel-autotune]\n\
         \x20                  [--kill-worker W --kill-epoch E [--rejoin-epoch R]] [--rebalance]\n\
         \x20 neutron-tp serve [--checkpoint F | --profile P [--warm-epochs K]]\n\
         \x20                  [--requests N] [--batch-size B] [--executor-threads N]\n\
         \x20 neutron-tp check [--all-profiles | same flags as train]\n\
         \x20 neutron-tp audit [--all-profiles | same flags as train]\n\
         \x20 neutron-tp plan  [workload flags as train] [--emit F] [--fast]\n\
         \x20 neutron-tp bench <{}|all> [--out DIR] [--fast]\n\
         \x20 neutron-tp inspect [--artifacts DIR]\n\n\
         systems: neutron_tp naive_tp dp_full dp_cache minibatch historical\n\n\
         communicator (cluster::Comm): --comm-all-to-all picks the split/gather\n\
         algorithm (naive bursts vs pairwise-exchange rounds), --comm-allreduce\n\
         the gradient sync (ring vs flat_tree), --bw-scale gives per-worker NIC\n\
         bandwidth multipliers (e.g. 0.25,1,1,1 = one straggler at quarter\n\
         bandwidth). Numerics are identical for every choice; only modeled\n\
         times change. TOML: [comm] all_to_all/allreduce/bw_scale.\n\
         --bf16-wire ships feature panels as bf16 (2 B/elem on the wire and\n\
         in staging tickets, f32 accumulate; TP systems only) — losses are\n\
         error-bounded, not bit-identical. TOML: [comm] bf16_wire.\n\n\
         kernel blocking ([kernel], DESIGN.md §5.3): --block-rows/--block-edges\n\
         override the CSR aggregation block geometry (0 = library defaults\n\
         256/32768; scheduling only, losses bit-identical for any setting);\n\
         --kernel-autotune lets `plan` micro-bench the lattice per (profile,\n\
         intra_threads) and pin the winner into the emitted TOML.\n\
         TOML: [kernel] block_rows/block_edges/autotune.\n\n\
         host staging ([mem], DESIGN.md §5.2): when the decoupled engine's\n\
         working set exceeds --device-mem-mb, panels swap over a modeled\n\
         PCIe link (--pcie-gbps bandwidth, prefetched --prefetch-depth steps\n\
         ahead so transfers hide under aggregation) instead of OOMing;\n\
         --no-swap restores the hard OOM. Baselines never swap (Table 2).\n\
         Swap traffic/stall/overlap is printed per epoch when engaged.\n\
         TOML: [mem] pcie_gbps/pcie_latency_us/prefetch_depth/swap.\n\n\
         static verification (analysis, DESIGN.md §8): `check` proves a run's\n\
         plans sound without executing an epoch — artifact shape/dtype flow,\n\
         the collective schedule (record-mode Comm), the host-staging byte\n\
         ledger, and chunk geometry; every violation names its site and the\n\
         knob that fixes it. `check --all-profiles` sweeps all builtin\n\
         profile x system combinations; `train`/`serve --pre-flight` run the\n\
         same pass and abort on errors before any epoch executes.\n\n\
         schedule auditor (analysis::audit, DESIGN.md §11): `audit` model-checks\n\
         the recorded execution schedule itself — every posted collective and\n\
         executor ticket joined exactly once in submission order, the staged-\n\
         memory prefetch admission proven deadlock-free under adversarial\n\
         transfer completion orders, every float reduction folding in canonical\n\
         order across the workers x intra_threads x pipeline x prefetch_depth\n\
         x swap lattice (the bit-identity contract, statically), and no\n\
         schedule window that silently drops an armed fault. `audit\n\
         --all-profiles` sweeps the builtin matrix; `--pre-flight` runs the\n\
         auditor together with `check`.\n\n\
         auto-planner (plan, DESIGN.md §10): `plan` searches system x\n\
         comm algorithms x chunk geometry x prefetch depth x intra threads\n\
         for the workload the other flags describe (profile, model, layers,\n\
         workers, --device-mem-mb, --bw-scale), scoring candidates on the\n\
         deterministic event sim without running any epoch, and writes the\n\
         winner to --emit (default plan.toml) — a ready-to-run TOML that\n\
         passes the pre-flight check (`train --config plan.toml`). Dominated\n\
         candidates (beaten on both modeled makespan and peak memory) are\n\
         pruned via a sound lower bound; --fast searches the per-axis seed\n\
         set only. The user's own settings are always candidates.\n\n\
         elastic training ([fault], DESIGN.md §9): --kill-worker W --kill-epoch E\n\
         models losing worker W mid-epoch E — the loss is detected at the next\n\
         collective, the partial epoch is discarded and replayed on the N-1\n\
         survivors; --rejoin-epoch R re-admits the worker at epoch R. --rebalance\n\
         refits NeutronTP's dim slices to measured per-worker comm rates between\n\
         epochs (straggler-aware; pairs well with --bw-scale). Losses stay\n\
         bit-identical to the undisturbed run; only modeled time changes. A\n\
         `--resume` may also change --workers: the checkpoint re-shards N->M\n\
         (decoupled TP only). TOML: [fault] kill_worker/kill_epoch/\n\
         rejoin_epoch/rebalance.\n\n\
         checkpoints: --checkpoint-dir saves <D>/{} (versioned binary:\n\
         params + Adam moments + epoch counter; atomic rename) after every\n\
         epoch; --resume continues from it bit-identically. `serve` loads a\n\
         checkpoint, runs the forward-only decoupled pass (2 embedding\n\
         collectives at any depth), then answers vertex queries in\n\
         micro-batches and prints a ServeReport (p50/p95/p99 latency, qps).",
        experiments::ALL.join("|"),
        checkpoint::FILE_NAME
    );
}

fn apply_flag_overrides(cfg: &mut RunConfig, flags: &Flags) -> anyhow::Result<()> {
    if let Some(v) = flags.get("profile") {
        cfg.profile = v.clone();
    }
    if let Some(v) = flags.get("system") {
        cfg.system = neutron_tp::config::System::from_str(v)?;
    }
    if let Some(v) = flags.get("model") {
        cfg.model = neutron_tp::config::ModelKind::from_str(v)?;
    }
    if let Some(v) = flags.get("task") {
        cfg.task = neutron_tp::config::Task::from_str(v)?;
    }
    if let Some(v) = flags.get("agg-impl") {
        cfg.agg_impl = neutron_tp::config::AggImpl::from_str(v)?;
    }
    if let Some(v) = flags.get("workers") {
        cfg.workers = v.parse()?;
    }
    if let Some(v) = flags.get("layers") {
        cfg.layers = v.parse()?;
    }
    if let Some(v) = flags.get("epochs") {
        cfg.epochs = v.parse()?;
    }
    if let Some(v) = flags.get("chunks") {
        cfg.chunks = v.parse()?;
    }
    if let Some(v) = flags.get("device-mem-mb") {
        cfg.device_mem_mb = v.parse()?;
    }
    if let Some(v) = flags.get("batch-size") {
        cfg.batch_size = v.parse()?;
    }
    if let Some(v) = flags.get("executor-threads") {
        cfg.executor_threads = v.parse()?;
    }
    if let Some(v) = flags.get("intra-threads") {
        cfg.intra_threads = v.parse()?;
    }
    if let Some(v) = flags.get("lr") {
        cfg.lr = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flags.get("feat-dim") {
        cfg.feat_dim = Some(v.parse()?);
    }
    if let Some(v) = flags.get("gpu-speedup") {
        cfg.net.gpu_speedup = v.parse()?;
    }
    if let Some(v) = flags.get("pcie-gbps") {
        cfg.mem.pcie_gbps = v.parse()?;
    }
    if let Some(v) = flags.get("prefetch-depth") {
        cfg.mem.prefetch_depth = v.parse()?;
    }
    if flags.has("no-swap") {
        cfg.mem.swap = false;
    }
    if flags.has("bf16-wire") {
        cfg.comm.bf16_wire = true;
    }
    if let Some(v) = flags.get("block-rows") {
        cfg.kernel.block_rows = v.parse()?;
    }
    if let Some(v) = flags.get("block-edges") {
        cfg.kernel.block_edges = v.parse()?;
    }
    if flags.has("kernel-autotune") {
        cfg.kernel.autotune = true;
    }
    if let Some(v) = flags.get("comm-all-to-all") {
        cfg.comm.all_to_all = neutron_tp::config::AllToAllAlgo::from_str(v)?;
    }
    if let Some(v) = flags.get("comm-allreduce") {
        cfg.comm.allreduce = neutron_tp::config::AllReduceAlgo::from_str(v)?;
    }
    if let Some(v) = flags.get("bw-scale") {
        cfg.comm.bw_scale = v
            .split(',')
            .map(|x| x.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("--bw-scale expects comma-separated numbers: {e}"))?;
    }
    if let Some(v) = flags.get("kill-worker") {
        cfg.fault.kill_worker = Some(v.parse()?);
    }
    if let Some(v) = flags.get("kill-epoch") {
        cfg.fault.kill_epoch = Some(v.parse()?);
    }
    if let Some(v) = flags.get("rejoin-epoch") {
        cfg.fault.rejoin_epoch = Some(v.parse()?);
    }
    if flags.has("rebalance") {
        cfg.fault.rebalance = true;
    }
    if let Some(v) = flags.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(v.clone());
    }
    if flags.has("resume") {
        cfg.resume = true;
    }
    if flags.has("no-pipeline") {
        cfg.pipeline = false;
    }
    if flags.has("no-fused-nn") {
        cfg.fused_nn = false;
    }
    if flags.has("no-chunk-sched") {
        cfg.chunk_sched = false;
    }
    Ok(())
}

fn train(flags: &Flags) -> anyhow::Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    apply_flag_overrides(&mut cfg, flags)?;
    cfg.validate()?;

    let store = ArtifactStore::load(artifacts_dir(flags))?;
    if flags.has("pre-flight") {
        pre_flight(&cfg, &store)?;
    }
    let p = datasets::profile(&cfg.profile).unwrap();
    eprintln!(
        "profile {} (stand-in for {}): |V|={} |E|={} d={} k={} h={}",
        p.name, p.stands_for, p.v, p.e, p.d, p.k, p.h
    );
    let data = match cfg.feat_dim {
        Some(d) => Dataset::generate_with_dim(p, d, cfg.seed),
        None => Dataset::generate(p, cfg.seed),
    };
    if cfg.comm.bf16_wire {
        println!(
            "bf16_wire=on: feature panels ship/store as bf16 (f32 accumulate), \
             per-round rel err <= {:.1e}",
            neutron_tp::tensor::bf16::REL_ERR_BOUND
        );
    }
    let pool = ExecutorPool::with_kernel(
        &store,
        cfg.executor_threads,
        cfg.intra_threads,
        cfg.kernel.block_rows,
        cfg.kernel.block_edges,
    )?;
    let ctx = Ctx { cfg: &cfg, data: &data, store: &store, pool: &pool };

    if cfg.fault.armed() {
        anyhow::ensure!(
            !cfg.resume,
            "--kill-worker/--kill-epoch model an in-run failure and cannot combine with \
             --resume (an N->M resume re-shards via --workers instead)"
        );
        let outcome = parallel::elastic::run_elastic_full(&ctx)?;
        for (e, r) in outcome.reports.iter().enumerate() {
            let swap = r.swap_row();
            println!(
                "epoch {e:>3}: {} | train_acc {:.3} test_acc {:.3} | wall {:.2}s{}{}",
                r.table_row(),
                r.train_acc,
                r.test_acc,
                r.wall_secs,
                if swap.is_empty() { "" } else { " | " },
                swap
            );
            if let Some(ev) = &r.fault {
                println!(
                    "  worker {} lost at collective {} ({:.1} us of partial epoch discarded); \
                     replayed on survivors",
                    ev.worker,
                    ev.at_collective,
                    r.recovery_secs * 1e6
                );
            }
        }
        if let Some(dir) = &cfg.checkpoint_dir {
            // record the cluster size the run ENDED on, so a later
            // --resume at a different --workers takes the re-shard path
            let mut meta = checkpoint::CheckpointMeta::of(&cfg);
            meta.workers = outcome.final_workers;
            let path = checkpoint::latest_path(dir);
            checkpoint::save(&path, &checkpoint::Checkpoint { meta, state: outcome.state })?;
        }
        return Ok(());
    }

    let mut engine = parallel::Engine::new(&ctx)?;
    let mut start_epoch = 0usize;
    if cfg.resume {
        let dir = cfg
            .checkpoint_dir
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("--resume needs --checkpoint-dir"))?;
        let path = checkpoint::latest_path(dir);
        let ckpt = checkpoint::load(&path)?;
        match ckpt.meta.compatible(&cfg)? {
            serve::ResumeMode::Exact => {}
            serve::ResumeMode::Reshard { from, to } => {
                eprintln!(
                    "elastic re-shard: checkpoint written by {from} worker(s), resuming on {to} \
                     (losses stay bit-identical; dim slices and chunk geometry re-derived)"
                );
            }
        }
        start_epoch = ckpt.state.epochs_done;
        engine.import_state(ckpt.state)?;
        eprintln!("resumed from {} after {start_epoch} epoch(s)", path.display());
        if start_epoch >= cfg.epochs {
            eprintln!(
                "checkpoint already has {start_epoch} epochs (>= --epochs {}); nothing to do",
                cfg.epochs
            );
        }
    }
    for e in start_epoch..cfg.epochs {
        let r = engine.run_epoch(&ctx)?;
        let swap = r.swap_row();
        println!(
            "epoch {e:>3}: {} | train_acc {:.3} test_acc {:.3} | wall {:.2}s{}{}",
            r.table_row(),
            r.train_acc,
            r.test_acc,
            r.wall_secs,
            if swap.is_empty() { "" } else { " | " },
            swap
        );
        if let Some(dir) = &cfg.checkpoint_dir {
            let path = checkpoint::latest_path(dir);
            let ckpt = checkpoint::Checkpoint {
                meta: checkpoint::CheckpointMeta::of(&cfg),
                state: engine.export_state(),
            };
            checkpoint::save(&path, &ckpt)?;
        }
    }
    Ok(())
}

fn serve_cmd(flags: &Flags) -> anyhow::Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    apply_flag_overrides(&mut cfg, flags)?;

    let store = ArtifactStore::load(artifacts_dir(flags))?;
    let loaded = match flags.get("checkpoint") {
        Some(f) => {
            let ckpt = checkpoint::load(std::path::Path::new(f))?;
            ckpt.meta.apply_to(&mut cfg);
            eprintln!(
                "checkpoint {}: {} on {} after {} epoch(s)",
                f,
                cfg.system.label(),
                cfg.profile,
                ckpt.state.epochs_done
            );
            Some(ckpt.state.params)
        }
        None => None,
    };
    cfg.validate()?;
    if flags.has("pre-flight") {
        pre_flight(&cfg, &store)?;
    }

    let p = datasets::profile(&cfg.profile).unwrap();
    let data = match cfg.feat_dim {
        Some(d) => Dataset::generate_with_dim(p, d, cfg.seed),
        None => Dataset::generate(p, cfg.seed),
    };
    let pool = ExecutorPool::with_kernel(
        &store,
        cfg.executor_threads,
        cfg.intra_threads,
        cfg.kernel.block_rows,
        cfg.kernel.block_edges,
    )?;
    let ctx = Ctx { cfg: &cfg, data: &data, store: &store, pool: &pool };

    let params = match loaded {
        Some(params) => params,
        None => {
            // no checkpoint: warm the parameters in-process first
            let warm: usize =
                flags.get("warm-epochs").map(|v| v.parse()).transpose()?.unwrap_or(1);
            eprintln!("no --checkpoint given: training {warm} warm epoch(s) on {}", cfg.profile);
            let mut engine = parallel::Engine::new(&ctx)?;
            for _ in 0..warm {
                engine.run_epoch(&ctx)?;
            }
            engine.export_state().params
        }
    };

    let opts = serve::ServeOptions {
        requests: flags.get("requests").map(|v| v.parse()).transpose()?.unwrap_or(256),
        batch_size: flags.get("batch-size").map(|v| v.parse()).transpose()?.unwrap_or(32),
        seed: cfg.seed ^ 0x5e7e,
    };
    let (report, engine) = serve::serve(&ctx, &params, &opts)?;
    println!("serve: {}", report.table_row());
    let comm_lines: Vec<String> = engine
        .comm_stats()
        .breakdown()
        .iter()
        .map(|(name, s)| {
            format!("{name} {:.1} KB / {:.1} us", s.bytes_sent as f64 / 1e3, s.secs * 1e6)
        })
        .collect();
    println!(
        "startup forward comm ({:.1} us simulated): {}",
        engine.sim_forward_secs() * 1e6,
        comm_lines.join(", ")
    );
    let sw = engine.swap_stats();
    if sw.engaged() {
        println!("startup forward {}", sw.one_liner());
    }
    println!(
        "test accuracy from served logits: {:.3}",
        engine.test_accuracy(&data)
    );
    let sample: Vec<u32> = (0..4.min(p.v as u32)).collect();
    let classes = engine.predict(&sample);
    for (id, cls) in sample.iter().zip(classes) {
        println!("  vertex {id} -> class {cls}");
    }
    Ok(())
}

fn bench(args: &[String], flags: &Flags) -> anyhow::Result<()> {
    let Some(which) = args.iter().find(|a| !a.starts_with("--")) else {
        anyhow::bail!("bench needs an experiment name or 'all'");
    };
    let store = ArtifactStore::load(artifacts_dir(flags))?;
    let fast = flags.has("fast");
    let names: Vec<&str> = if which == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![which.as_str()]
    };
    let out_dir = flags.get("out").cloned();
    if let Some(d) = &out_dir {
        std::fs::create_dir_all(d)?;
    }
    for name in names {
        eprintln!("== running {name} ==");
        let t0 = std::time::Instant::now();
        let text = experiments::run_experiment(name, &store, fast)?;
        println!("{text}");
        eprintln!("== {name} done in {:.1}s ==", t0.elapsed().as_secs_f64());
        if let Some(d) = &out_dir {
            // JSON-shaped experiments (plan_scale) keep their extension honest
            let ext = if text.trim_start().starts_with('{') { "json" } else { "csv" };
            std::fs::write(format!("{d}/{name}.{ext}"), &text)?;
        }
    }
    Ok(())
}

/// `neutron-tp check`: static plan/schedule verification (DESIGN.md §8).
/// Default mode verifies the one config `train` would run; `--all-profiles`
/// sweeps every builtin profile x system combination.
fn check_cmd(flags: &Flags) -> anyhow::Result<()> {
    let store = ArtifactStore::load(artifacts_dir(flags))?;
    if flags.has("all-profiles") {
        return check_all_profiles(&store);
    }
    let mut cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    apply_flag_overrides(&mut cfg, flags)?;
    let findings = analysis::check_run(&cfg, &store);
    for f in &findings {
        println!("{f}");
    }
    let errors = findings
        .iter()
        .filter(|f| f.severity == analysis::Severity::Error)
        .count();
    if errors > 0 {
        anyhow::bail!(
            "check failed: {errors} error(s), {} warning(s) for {} on {}",
            findings.len() - errors,
            cfg.system.label(),
            cfg.profile
        );
    }
    println!(
        "check clean: {} on {} ({} warning(s))",
        cfg.system.label(),
        cfg.profile,
        findings.len()
    );
    Ok(())
}

/// `neutron-tp audit`: happens-before model check of the recorded
/// execution schedule (DESIGN.md §11). Default mode audits the one
/// config `train` would run (including the cross-lattice determinism
/// proof); `--all-profiles` sweeps every builtin profile x system
/// combination.
fn audit_cmd(flags: &Flags) -> anyhow::Result<()> {
    let store = ArtifactStore::load(artifacts_dir(flags))?;
    if flags.has("all-profiles") {
        return audit_all_profiles(&store);
    }
    let mut cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    apply_flag_overrides(&mut cfg, flags)?;
    let findings = analysis::audit::audit_run(&cfg, &store);
    for f in &findings {
        println!("{f}");
    }
    let errors = findings
        .iter()
        .filter(|f| f.severity == analysis::Severity::Error)
        .count();
    if errors > 0 {
        anyhow::bail!(
            "audit failed: {errors} error(s), {} warning(s) for {} on {}",
            findings.len() - errors,
            cfg.system.label(),
            cfg.profile
        );
    }
    println!(
        "audit clean: {} on {} ({} warning(s))",
        cfg.system.label(),
        cfg.profile,
        findings.len()
    );
    Ok(())
}

fn audit_all_profiles(store: &ArtifactStore) -> anyhow::Result<()> {
    let mut failed = 0usize;
    for p in datasets::PROFILES {
        // one graph per profile, shared across all six systems
        let g = Dataset::generate_graph(*p, RunConfig::default().seed);
        for &system in neutron_tp::config::System::ALL {
            let mut cfg = RunConfig::default();
            cfg.profile = p.name.to_string();
            cfg.system = system;
            let mut findings = analysis::audit::audit_with_graph(&cfg, p, &g, store);
            // the lattice proof once per profile for the engine under
            // contract (decoupled TP) and the DP yardstick — naive TP
            // shares the decoupled schedule machinery
            if matches!(
                system,
                neutron_tp::config::System::NeutronTp | neutron_tp::config::System::DpFull
            ) {
                findings.extend(analysis::audit::audit_lattice(&cfg, p, &g, store));
            }
            let errors = findings
                .iter()
                .filter(|f| f.severity == analysis::Severity::Error)
                .count();
            println!(
                "{:<6} x {:<12} {}",
                p.name,
                system.name(),
                if findings.is_empty() {
                    "audit clean".to_string()
                } else {
                    format!("{errors} error(s), {} warning(s)", findings.len() - errors)
                }
            );
            for f in &findings {
                println!("  {f}");
            }
            if errors > 0 {
                failed += 1;
            }
        }
    }
    if failed > 0 {
        anyhow::bail!("audit --all-profiles: {failed} combination(s) with errors");
    }
    Ok(())
}

/// `neutron-tp plan`: search the configuration space for this workload
/// and emit the winner as a ready-to-run TOML (DESIGN.md §10). The
/// workload flags describe the scenario; the searched axes (system,
/// collective algorithms, chunk geometry, prefetch depth, kernel team
/// width) are re-chosen by the planner, with the user's own values kept
/// in the running as candidates.
fn plan_cmd(flags: &Flags) -> anyhow::Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => RunConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    apply_flag_overrides(&mut cfg, flags)?;
    let store = ArtifactStore::load(artifacts_dir(flags))?;
    let fast = flags.has("fast");
    let t0 = std::time::Instant::now();
    let outcome = neutron_tp::plan::plan(&cfg, &store, fast)?;

    let (mut pruned, mut infeasible) = (0usize, 0usize);
    for s in &outcome.result.skipped {
        match s {
            neutron_tp::plan::Skipped::Dominated { .. } => pruned += 1,
            neutron_tp::plan::Skipped::Infeasible { .. } => infeasible += 1,
        }
    }
    eprintln!(
        "plan: {} candidate(s){}; {} fully scored, {} pruned as dominated, {} infeasible ({:.2}s)",
        outcome.result.candidates,
        if fast { " (--fast: seed set only)" } else { "" },
        outcome.result.scored.len(),
        pruned,
        infeasible,
        t0.elapsed().as_secs_f64(),
    );
    println!("fixed defaults (the yardsticks):");
    for (system, score) in &outcome.defaults {
        match score {
            Some(s) => println!(
                "  {:<12} modeled epoch {:>10.3} ms  peak mem {:>8.1} MiB",
                system.name(),
                s.makespan_secs * 1e3,
                s.peak_mem_bytes as f64 / (1024.0 * 1024.0)
            ),
            None => println!("  {:<12} infeasible for this scenario", system.name()),
        }
    }
    let w = outcome.winner();
    let c = &w.cfg;
    println!(
        "winner: {} (all_to_all {}, allreduce {}, chunks {}, pipeline {}, \
         prefetch_depth {}, intra_threads {})",
        c.system.name(),
        c.comm.all_to_all.name(),
        c.comm.allreduce.name(),
        c.chunks,
        if c.pipeline { "on" } else { "off" },
        c.mem.prefetch_depth,
        c.intra_threads
    );
    if cfg.kernel.autotune {
        println!(
            "  kernel blocks autotuned for ({}, intra_threads {}): block_rows {} block_edges {}",
            c.profile, c.intra_threads, c.kernel.block_rows, c.kernel.block_edges
        );
    }
    let best_default = outcome
        .defaults
        .iter()
        .filter_map(|(_, s)| s.as_ref())
        .map(|s| s.makespan_secs)
        .fold(f64::INFINITY, f64::min);
    println!(
        "  modeled epoch {:.3} ms  peak mem {:.1} MiB  ({:.2}x vs best fixed default)",
        w.score.makespan_secs * 1e3,
        w.score.peak_mem_bytes as f64 / (1024.0 * 1024.0),
        best_default / w.score.makespan_secs.max(1e-12)
    );

    // self-verify before writing: the emitted TOML must parse back to the
    // winner bit-for-bit and pass the same static pass `--pre-flight` runs
    let verified = analysis::check_plan_toml(&outcome.winner_toml, &store)?;
    anyhow::ensure!(
        verified == w.cfg,
        "plan TOML round-trip drifted from the winner (config serializer bug)"
    );
    let out = flags.get("emit").cloned().unwrap_or_else(|| "plan.toml".to_string());
    std::fs::write(&out, &outcome.winner_toml)?;
    println!("wrote {out} (pre-flight clean; run it: neutron-tp train --config {out})");
    Ok(())
}

fn check_all_profiles(store: &ArtifactStore) -> anyhow::Result<()> {
    let mut failed = 0usize;
    for p in datasets::PROFILES {
        // one graph per profile, shared across all six systems
        let g = Dataset::generate_graph(*p, RunConfig::default().seed);
        for &system in neutron_tp::config::System::ALL {
            let mut cfg = RunConfig::default();
            cfg.profile = p.name.to_string();
            cfg.system = system;
            let findings = analysis::check_with_graph(&cfg, p, &g, store);
            let errors = findings
                .iter()
                .filter(|f| f.severity == analysis::Severity::Error)
                .count();
            println!(
                "{:<6} x {:<12} {}",
                p.name,
                system.name(),
                if findings.is_empty() {
                    "clean".to_string()
                } else {
                    format!("{errors} error(s), {} warning(s)", findings.len() - errors)
                }
            );
            for f in &findings {
                println!("  {f}");
            }
            if errors > 0 {
                failed += 1;
            }
        }
    }
    if failed > 0 {
        anyhow::bail!("check --all-profiles: {failed} combination(s) with errors");
    }
    Ok(())
}

/// `--pre-flight`: run the static verifier AND the schedule auditor
/// before committing to a train/serve run; errors abort before any
/// epoch executes.
fn pre_flight(cfg: &RunConfig, store: &ArtifactStore) -> anyhow::Result<()> {
    let mut findings = analysis::check_run(cfg, store);
    findings.extend(analysis::audit::audit_run(cfg, store));
    for f in &findings {
        eprintln!("pre-flight: {f}");
    }
    if analysis::has_errors(&findings) {
        anyhow::bail!(
            "pre-flight check failed ({} finding(s)); see `neutron-tp check` / `neutron-tp audit`",
            findings.len()
        );
    }
    eprintln!("pre-flight check clean ({} warning(s))", findings.len());
    Ok(())
}

fn inspect(flags: &Flags) -> anyhow::Result<()> {
    let store = ArtifactStore::load(artifacts_dir(flags))?;
    println!(
        "artifact store: {} artifacts (dim_tile={}, row_block={})",
        store.len(),
        store.dim_tile,
        store.row_block
    );
    for p in datasets::PROFILES {
        println!(
            "profile {:>5} -> {:<22} |V|={:<7} |E|={:<9} d={:<4} k={:<3} h={}",
            p.name, p.stands_for, p.v, p.e, p.d, p.k, p.h
        );
    }
    Ok(())
}

/// `--key value` and `--switch` flags.
struct Flags(std::collections::BTreeMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut map = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let next_is_val = args.get(i + 1).is_some_and(|a| !a.starts_with("--"));
                if next_is_val {
                    map.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    map.insert(key.to_string(), String::new());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Flags(map)
    }

    fn get(&self, key: &str) -> Option<&String> {
        self.0.get(key).filter(|v| !v.is_empty())
    }

    fn has(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }
}
