//! Modeled worker failure and elastic dim-slice bookkeeping
//! (DESIGN.md §9).
//!
//! Tensor parallelism makes elasticity cheap: a worker owns a *column
//! range* of the embedding panel, not a graph partition, so losing or
//! adding a worker is a re-derivation of `dim_slices` — pure bookkeeping,
//! no vertex dependencies to re-home. This module holds the pieces the
//! elastic layer shares:
//!
//! * [`FaultEvent`] — the deterministic record a [`super::Comm`] armed via
//!   `Comm::for_epoch` writes when the modeled worker "dies" at its
//!   scheduled collective. Engines finish the epoch normally (the data
//!   plane is host-side); the elastic driver reads the event off the
//!   epoch report, discards the partial epoch, and re-replays it on the
//!   survivors.
//! * [`weighted_dim_slices`] — dim-slice widths proportional to per-worker
//!   speed weights (largest-remainder rounding, exact cover of `[0, d)`).
//!   Slice widths only steer modeled timing and the split/gather byte
//!   plan, never the aggregation numerics, so re-balancing is loss-free
//!   by construction (DESIGN.md §9.3).
//! * [`refit_weights`] — turn one epoch's per-worker NIC feedback into the
//!   next epoch's slice weights (the straggler re-balancer).

use std::ops::Range;

/// A modeled worker loss, recorded by the communicator at the collective
/// it was armed for. Deterministic: same config, same epoch, same event.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// the worker that died
    pub worker: usize,
    /// ordinal of the collective (1-based, within the epoch's
    /// communicator) at which the loss is detected
    pub at_collective: usize,
    /// simulated makespan at detection — the modeled time the partial
    /// epoch wasted before the survivors could react
    pub at_secs: f64,
}

/// Contiguous dim slices of `[0, d)` with widths proportional to
/// `weights` (per-worker speed estimates). Largest-remainder rounding;
/// ties go to the lower index, so the result is deterministic. Degenerate
/// weights (non-finite or non-positive entries) fall back to uniform.
///
/// The cover invariant — slices are adjacent, disjoint, and sum to `d` —
/// is what keeps re-balancing loss-free: split/gather move exactly the
/// same scalars under any cover (DESIGN.md §9.3), only the per-worker
/// byte volumes (and thus modeled times) shift.
pub fn weighted_dim_slices(d: usize, weights: &[f64]) -> Vec<Range<usize>> {
    let n = weights.len();
    assert!(n > 0, "weighted_dim_slices needs at least one worker");
    let uniform = vec![1.0; n];
    let ws: &[f64] = if weights.iter().all(|w| w.is_finite() && *w > 0.0) {
        weights
    } else {
        &uniform
    };
    let total: f64 = ws.iter().sum();
    let ideal: Vec<f64> = ws.iter().map(|w| d as f64 * w / total).collect();
    let mut width: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
    // distribute the remainder by largest fractional part, lower index
    // first on ties; the trim loop only runs if fp error over-assigned
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let (fa, fb) = (ideal[a] - ideal[a].floor(), ideal[b] - ideal[b].floor());
        fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut assigned: usize = width.iter().sum();
    let mut k = 0usize;
    while assigned < d {
        width[order[k % n]] += 1;
        assigned += 1;
        k += 1;
    }
    k = 0;
    while assigned > d {
        let i = order[n - 1 - (k % n)];
        if width[i] > 0 {
            width[i] -= 1;
            assigned -= 1;
        }
        k += 1;
    }
    let mut slices = Vec::with_capacity(n);
    let mut start = 0usize;
    for w in width {
        slices.push(start..start + w);
        start += w;
    }
    slices
}

/// Next-epoch slice weights from one epoch's feedback: worker `w` moved a
/// `widths[w]`-column slice in `comm_secs[w]` NIC-busy seconds, so its
/// effective speed is `widths[w] / comm_secs[w]` columns per second
/// (`Topology::bw_scale` shows up here without being read directly — a
/// straggler NIC takes longer per column). Returns `None` on degenerate
/// feedback (an empty slice or a worker with no measured traffic), in
/// which case the caller keeps its current slicing.
pub fn refit_weights(widths: &[usize], comm_secs: &[f64]) -> Option<Vec<f64>> {
    if widths.len() != comm_secs.len() || widths.len() < 2 {
        return None;
    }
    let mut ws = Vec::with_capacity(widths.len());
    for (&wd, &s) in widths.iter().zip(comm_secs) {
        if wd == 0 || !s.is_finite() || s <= 0.0 {
            return None;
        }
        ws.push(wd as f64 / s);
    }
    Some(ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dim_slices;

    fn assert_cover(slices: &[Range<usize>], d: usize) {
        let mut next = 0usize;
        for s in slices {
            assert_eq!(s.start, next, "slices must be adjacent: {slices:?}");
            assert!(s.end >= s.start);
            next = s.end;
        }
        assert_eq!(next, d, "slices must cover [0, {d}): {slices:?}");
    }

    #[test]
    fn uniform_weights_match_dim_slices() {
        for (d, n) in [(64usize, 4usize), (61, 4), (7, 3), (3, 4), (1, 8)] {
            let got = weighted_dim_slices(d, &vec![1.0; n]);
            assert_eq!(got, dim_slices(d, n), "d={d} n={n}");
        }
    }

    #[test]
    fn skewed_weights_shift_width_toward_fast_workers() {
        let s = weighted_dim_slices(64, &[0.25, 1.0, 1.0, 1.0]);
        assert_cover(&s, 64);
        assert!(
            s[0].len() < s[1].len(),
            "straggler kept {} columns vs {}",
            s[0].len(),
            s[1].len()
        );
        // 64 * 0.25/3.25 ≈ 4.9 → the straggler gets ~5 columns
        assert!(s[0].len() <= 6, "straggler width {}", s[0].len());
    }

    #[test]
    fn degenerate_weights_fall_back_to_uniform() {
        for bad in [vec![0.0, 1.0], vec![f64::NAN, 1.0], vec![-1.0, 1.0]] {
            assert_eq!(weighted_dim_slices(10, &bad), dim_slices(10, 2));
        }
    }

    #[test]
    fn extreme_skew_may_empty_a_slice_but_still_covers() {
        let s = weighted_dim_slices(4, &[1e-9, 1.0, 1.0, 1.0]);
        assert_cover(&s, 4);
    }

    #[test]
    fn refit_inverts_nic_time() {
        // worker 0 took 4x the time per column: its weight drops 4x
        let ws = refit_weights(&[16, 16], &[4.0, 1.0]).unwrap();
        assert!((ws[0] / ws[1] - 0.25).abs() < 1e-12, "{ws:?}");
        // degenerate feedback declines to refit
        assert_eq!(refit_weights(&[16, 0], &[1.0, 1.0]), None);
        assert_eq!(refit_weights(&[16, 16], &[1.0, 0.0]), None);
        assert_eq!(refit_weights(&[16], &[1.0]), None);
    }
}
