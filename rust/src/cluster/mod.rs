//! Simulated multi-worker cluster (DESIGN.md §3/§4): real data movement on
//! shared memory, timing from a discrete-event simulation fed by measured
//! device durations and the network cost model.

pub mod collectives;
pub mod event;

pub use event::{EventSim, StreamKind};
