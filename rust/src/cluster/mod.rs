//! Simulated multi-worker cluster (DESIGN.md §3/§4): real data movement on
//! shared memory, timing from a discrete-event simulation fed by measured
//! device durations and the network cost model. Engines speak to the
//! cluster exclusively through [`comm::Comm`], the per-run communicator
//! that owns the event sim and exposes nonblocking, topology-aware
//! collectives.

pub mod comm;
pub mod event;
pub mod fault;

pub use comm::{
    Comm, CommHandle, CommKind, CommStats, CommTrace, DoneTimes, KindStats, ReduceSite, Rounds,
    Topology, TraceEvent, STAGE_NO_DEP,
};
pub use event::{EventSim, StreamKind};
pub use fault::{refit_weights, weighted_dim_slices, FaultEvent};
