//! Collective operations: the data plane really moves the bytes between
//! worker-local buffers (so numerics are exact), while the event sim +
//! network model account the wire time per worker.
//!
//! GNN tensor parallelism needs exactly two collectives (paper §3.1):
//! * `gather` — dim-sliced `[V, D/N]` per worker → vertex-sliced
//!   `[V/N, D]` per worker (before NN ops, which need complete rows);
//! * `split`  — the inverse (before graph ops, which need dim slices).
//! Both are all-to-alls of `(V/N) x (D/N)` blocks: every worker exchanges
//! the same volume, which is the load-balance argument of §3.2.
//!
//! Plus `allreduce` for parameter gradients and the *sequential broadcast*
//! the SANCUS-like baseline uses (its communication pathology in §5.2).

use std::ops::Range;

use super::event::EventSim;
use crate::config::NetModel;
use crate::tensor::Matrix;

/// Per-worker completion times of a collective.
pub type DoneTimes = Vec<f64>;

/// All-to-all timing for symmetric block exchange: every worker sends and
/// receives `N-1` blocks; full-duplex, so the NIC occupancy is
/// `max(sent, received)` wire time plus per-message latency.
fn all_to_all_times(
    sim: &mut EventSim,
    net: &NetModel,
    sent_bytes: &[usize],
    recv_bytes: &[usize],
    ready: &[f64],
) -> DoneTimes {
    let n = sim.workers();
    let mut done = vec![0.0; n];
    for w in 0..n {
        let wire = net
            .wire_secs(sent_bytes[w])
            .max(net.wire_secs(recv_bytes[w]))
            + net.latency_us * 1e-6 * (n.saturating_sub(1)) as f64;
        done[w] = sim.comm(w, wire, ready[w]);
    }
    done
}

/// `split`: vertex-sliced full-width inputs → dim-sliced outputs.
///
/// `inputs[i]` holds rows `row_parts[i]` with full width `D`; the output
/// `out[j]` holds all `V` rows restricted to columns `dim_parts[j]`.
pub fn split(
    sim: &mut EventSim,
    net: &NetModel,
    inputs: &[Matrix],
    row_parts: &[Range<usize>],
    dim_parts: &[Range<usize>],
    ready: &[f64],
) -> (Vec<Matrix>, DoneTimes) {
    let n = inputs.len();
    let v: usize = row_parts.iter().map(Range::len).sum();
    let mut outs: Vec<Matrix> = dim_parts.iter().map(|d| Matrix::zeros(v, d.len())).collect();
    let mut sent = vec![0usize; n];
    let mut recv = vec![0usize; n];
    for i in 0..n {
        for (j, dp) in dim_parts.iter().enumerate() {
            let block = inputs[i].slice_cols(dp.clone());
            let bytes = block.bytes();
            if i != j {
                sent[i] += bytes;
                recv[j] += bytes;
            }
            outs[j].write_rows(row_parts[i].start, &block);
        }
    }
    let done = all_to_all_times(sim, net, &sent, &recv, ready);
    (outs, done)
}

/// `gather`: dim-sliced inputs → vertex-sliced full-width outputs.
pub fn gather(
    sim: &mut EventSim,
    net: &NetModel,
    inputs: &[Matrix],
    row_parts: &[Range<usize>],
    dim_parts: &[Range<usize>],
    ready: &[f64],
) -> (Vec<Matrix>, DoneTimes) {
    let n = inputs.len();
    let d: usize = dim_parts.iter().map(Range::len).sum();
    let mut outs: Vec<Matrix> = row_parts
        .iter()
        .map(|r| Matrix::zeros(r.len(), d))
        .collect();
    let mut sent = vec![0usize; n];
    let mut recv = vec![0usize; n];
    for (j, dp) in dim_parts.iter().enumerate() {
        for (i, rp) in row_parts.iter().enumerate() {
            let block = inputs[j].slice_rows(rp.clone());
            let bytes = block.bytes();
            if i != j {
                sent[j] += bytes;
                recv[i] += bytes;
            }
            outs[i].write_cols(dp.start, &block);
        }
    }
    let done = all_to_all_times(sim, net, &sent, &recv, ready);
    (outs, done)
}

/// Ring allreduce (sum) over per-worker equally-shaped tensors, e.g.
/// parameter gradients. Cost: `2 (N-1)/N * bytes` wire per worker.
pub fn allreduce_sum(
    sim: &mut EventSim,
    net: &NetModel,
    inputs: &[Matrix],
    ready: &[f64],
) -> (Matrix, DoneTimes) {
    let n = inputs.len();
    let mut sum = inputs[0].clone();
    for m in &inputs[1..] {
        sum.add_assign(m);
    }
    let bytes = sum.bytes();
    let mut done = vec![0.0; n];
    if n > 1 {
        let wire = 2.0 * (n - 1) as f64 / n as f64 * net.wire_secs(bytes)
            + 2.0 * (n - 1) as f64 * net.latency_us * 1e-6;
        for w in 0..n {
            done[w] = sim.comm(w, wire, ready[w]);
        }
        // ring steps synchronize all participants
        let t = done.iter().copied().fold(0.0, f64::max);
        done.iter_mut().for_each(|d| *d = t);
    } else {
        done[0] = ready[0];
    }
    (sum, done)
}

/// All-gather of per-worker row blocks into the full matrix everywhere
/// (used for sharing precomputed attention scores, paper §4.1.1). Block
/// `i` lands at the global rows `row_parts[i]` describes, so callers may
/// pass any (disjoint, covering) row partition.
pub fn allgather_rows(
    sim: &mut EventSim,
    net: &NetModel,
    inputs: &[Matrix],
    row_parts: &[Range<usize>],
    ready: &[f64],
) -> (Matrix, DoneTimes) {
    let n = inputs.len();
    debug_assert_eq!(row_parts.len(), n);
    let v: usize = row_parts.iter().map(Range::len).sum();
    let d = inputs[0].cols();
    let mut full = Matrix::zeros(v, d);
    let mut total_bytes = 0usize;
    for (i, rp) in row_parts.iter().enumerate() {
        debug_assert_eq!(inputs[i].rows(), rp.len());
        full.write_rows(rp.start, &inputs[i]);
        total_bytes += inputs[i].bytes();
    }
    let mut done = vec![0.0; n];
    for w in 0..n {
        let sent = inputs[w].bytes() * (n - 1);
        let recvd = total_bytes - inputs[w].bytes();
        let wire = net.wire_secs(sent.max(recvd))
            + net.latency_us * 1e-6 * (n.saturating_sub(1)) as f64;
        done[w] = sim.comm(w, wire, ready[w]);
    }
    (full, done)
}

/// SANCUS-style *sequential* broadcast: worker after worker broadcasts its
/// full local block to everyone, each waiting for the previous broadcast —
/// the serialization the paper blames for Sancus's poor scaling (§5.2).
///
/// Sender/receiver costs are asymmetric: the sender's NIC transmits its
/// block to all `n-1` peers, while each receiver only ingests one copy.
/// The round still ends at the slowest participant (the sender), which is
/// what serializes the cluster.
pub fn sequential_broadcast(
    sim: &mut EventSim,
    net: &NetModel,
    inputs: &[Matrix],
    ready: &[f64],
) -> (Matrix, DoneTimes) {
    let n = inputs.len();
    let full = Matrix::concat_rows(inputs);
    let mut frontier = ready.iter().copied().fold(0.0, f64::max);
    for s in 0..n {
        let peers = n.saturating_sub(1);
        let send_dur =
            net.wire_secs(inputs[s].bytes() * peers) + net.latency_us * 1e-6 * peers as f64;
        let recv_dur = net.msg_secs(inputs[s].bytes());
        let mut next = frontier;
        for w in 0..n {
            let dur = if w == s { send_dur } else { recv_dur };
            let d = sim.comm(w, dur, frontier);
            next = next.max(d);
        }
        frontier = next;
    }
    (full, vec![frontier; n])
}

/// Point-to-point fetch of specific rows from an owner worker (DepComm
/// neighbour pull). Returns the fetched rows and the requester's done time.
#[allow(clippy::too_many_arguments)]
pub fn fetch_rows(
    sim: &mut EventSim,
    net: &NetModel,
    owner_data: &Matrix,
    owner_base: usize,
    rows: &[u32],
    owner: usize,
    requester: usize,
    ready: f64,
) -> (Matrix, f64) {
    let local: Vec<u32> = rows.iter().map(|&r| r - owner_base as u32).collect();
    let block = owner_data.gather_rows(&local);
    let dur = net.msg_secs(block.bytes());
    // occupies both NICs
    let t_owner = sim.comm(owner, dur, ready);
    let t_req = sim.comm(requester, dur, ready.max(t_owner - dur));
    (block, t_req.max(t_owner))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dim_slices, row_slices};

    fn net() -> NetModel {
        NetModel::default()
    }

    /// split then gather must reproduce the original vertex-sliced data.
    #[test]
    fn split_gather_roundtrip() {
        let (v, d, n) = (12, 10, 4);
        let full = Matrix::from_fn(v, d, |r, c| (r * 100 + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut sim = EventSim::new(n);
        let ready = vec![0.0; n];
        let (sliced, t1) = split(&mut sim, &net(), &inputs, &rp, &dp, &ready);
        for (j, s) in sliced.iter().enumerate() {
            assert_eq!(*s, full.slice_cols(dp[j].clone()));
        }
        let (back, _t2) = gather(&mut sim, &net(), &sliced, &rp, &dp, &t1);
        for (i, b) in back.iter().enumerate() {
            assert_eq!(*b, inputs[i]);
        }
    }

    /// Non-divisible shapes: V and D not multiples of N exercise the
    /// `row_slices`/`dim_slices` remainder paths (first slices one wider).
    #[test]
    fn split_gather_roundtrip_non_divisible() {
        for (v, d, n) in [(13usize, 10usize, 4usize), (7, 5, 3), (17, 9, 8), (5, 4, 5)] {
            let full = Matrix::from_fn(v, d, |r, c| (r * 100 + c) as f32);
            let rp = row_slices(v, n);
            let dp = dim_slices(d, n);
            assert_eq!(rp.iter().map(|r| r.len()).sum::<usize>(), v);
            assert_eq!(dp.iter().map(|r| r.len()).sum::<usize>(), d);
            let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
            let mut sim = EventSim::new(n);
            let ready = vec![0.0; n];
            let (sliced, t1) = split(&mut sim, &net(), &inputs, &rp, &dp, &ready);
            for (j, s) in sliced.iter().enumerate() {
                assert_eq!(*s, full.slice_cols(dp[j].clone()), "v={v} d={d} n={n} slice {j}");
            }
            let (back, _) = gather(&mut sim, &net(), &sliced, &rp, &dp, &t1);
            for (i, b) in back.iter().enumerate() {
                assert_eq!(*b, inputs[i], "v={v} d={d} n={n} worker {i}");
            }
        }
    }

    /// Remainder slices differ by at most one row/column, so the all-to-all
    /// volume stays balanced to within one slice row.
    #[test]
    fn non_divisible_comm_nearly_balanced() {
        let (v, d, n) = (1021usize, 61usize, 4usize); // both indivisible by 4
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut sim = EventSim::new(n);
        let _ = split(&mut sim, &net(), &inputs, &rp, &dp, &vec![0.0; n]);
        let comm = sim.comm_totals();
        let max = comm.iter().copied().fold(0.0, f64::max);
        let min = comm.iter().copied().fold(f64::MAX, f64::min);
        assert!(max / min < 1.05, "remainder imbalance {max}/{min}");
    }

    #[test]
    fn allgather_places_blocks_by_row_parts() {
        let (v, d, n) = (11usize, 3usize, 3usize);
        let full = Matrix::from_fn(v, d, |r, c| (10 * r + c) as f32);
        let rp = row_slices(v, n);
        let blocks: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut sim = EventSim::new(n);
        let (got, done) = allgather_rows(&mut sim, &net(), &blocks, &rp, &vec![0.0; n]);
        assert_eq!(got, full);
        assert!(done.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn split_comm_time_balanced() {
        let (v, d, n) = (1024, 64, 4);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut sim = EventSim::new(n);
        let (_, _) = split(&mut sim, &net(), &inputs, &rp, &dp, &vec![0.0; n]);
        let comm = sim.comm_totals();
        let max = comm.iter().copied().fold(0.0, f64::max);
        let min = comm.iter().copied().fold(f64::MAX, f64::min);
        assert!(max / min < 1.001, "TP collectives are perfectly balanced");
    }

    #[test]
    fn allreduce_sums_and_times() {
        let n = 4;
        let inputs: Vec<Matrix> =
            (0..n).map(|i| Matrix::from_fn(3, 3, |_, _| i as f32)).collect();
        let mut sim = EventSim::new(n);
        let (sum, done) = allreduce_sum(&mut sim, &net(), &inputs, &vec![0.0; n]);
        assert_eq!(sum.get(0, 0), 0.0 + 1.0 + 2.0 + 3.0);
        assert!(done.iter().all(|&t| t > 0.0));
        assert!(done.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12));
    }

    #[test]
    fn sequential_broadcast_serializes() {
        let n = 4;
        let rows = 256;
        let inputs: Vec<Matrix> = (0..n).map(|_| Matrix::zeros(rows, 64)).collect();
        let rp = row_slices(rows * n, n);
        // sancus-style sequential broadcast strictly slower than allgather
        let mut s1 = EventSim::new(n);
        let (_, d1) = sequential_broadcast(&mut s1, &net(), &inputs, &vec![0.0; n]);
        let mut s2 = EventSim::new(n);
        let (_, d2) = allgather_rows(&mut s2, &net(), &inputs, &rp, &vec![0.0; n]);
        assert!(d1[0] > d2[0] * 1.5, "seq {} vs allgather {}", d1[0], d2[0]);
    }

    #[test]
    fn fetch_rows_moves_right_data() {
        let owner_rows = Matrix::from_fn(8, 4, |r, c| (r * 10 + c) as f32);
        let mut sim = EventSim::new(2);
        // owner 1 owns global rows 8..16
        let (block, t) = fetch_rows(&mut sim, &net(), &owner_rows, 8, &[9, 12], 1, 0, 0.0);
        assert_eq!(block.row(0), owner_rows.row(1));
        assert_eq!(block.row(1), owner_rows.row(4));
        assert!(t > 0.0);
    }

    #[test]
    fn gather_volume_constant_in_workers() {
        // paper §3.2: TP total communication ~ 2 V D per round, independent
        // of N — check gather totals stay ~flat as N grows
        let (v, d) = (1024, 64);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let mut totals = Vec::new();
        for n in [2usize, 4, 8] {
            let rp = row_slices(v, n);
            let dp = dim_slices(d, n);
            let sliced: Vec<Matrix> =
                dp.iter().map(|dpj| full.slice_cols(dpj.clone())).collect();
            let mut sim = EventSim::new(n);
            // isolate wire time: latency scales with peer count by design
            let net0 = NetModel { latency_us: 0.0, ..NetModel::default() };
            let _ = gather(&mut sim, &net0, &sliced, &rp, &dp, &vec![0.0; n]);
            totals.push(sim.comm_totals().iter().sum::<f64>());
        }
        // total wire converges to (N-1)/N * V*D*4/bw: bounded, not linear
        // in N (ratio n=8 : n=2 is exactly 1.75)
        assert!(totals[2] < totals[0] * 1.8, "{totals:?}");
        assert!(totals[2] > totals[1], "monotone but saturating: {totals:?}");
    }
}
