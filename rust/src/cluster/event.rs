//! Discrete-event simulation of per-worker timelines.
//!
//! Each worker has two streams — compute (the device) and comm (the NIC) —
//! that can overlap, which is exactly what chunk pipelining (paper §4.2.2)
//! exploits. Engines schedule operations with explicit data-dependency
//! ready times; the sim assigns start = max(ready, stream_free) and records
//! busy intervals for the GPU-utilization figure (Fig 15) and per-worker
//! comp/comm totals for Table 2's max/min rows.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamKind {
    Compute,
    Comm,
}

#[derive(Clone, Debug)]
pub struct Interval {
    pub start: f64,
    pub end: f64,
    pub kind: StreamKind,
}

#[derive(Clone, Debug)]
pub struct EventSim {
    compute_free: Vec<f64>,
    comm_free: Vec<f64>,
    comp_total: Vec<f64>,
    comm_total: Vec<f64>,
    intervals: Vec<Vec<Interval>>,
}

impl EventSim {
    pub fn new(workers: usize) -> Self {
        Self {
            compute_free: vec![0.0; workers],
            comm_free: vec![0.0; workers],
            comp_total: vec![0.0; workers],
            comm_total: vec![0.0; workers],
            intervals: vec![Vec::new(); workers],
        }
    }

    pub fn workers(&self) -> usize {
        self.compute_free.len()
    }

    /// Schedule `dur` seconds of compute on worker `w`, not before `ready`.
    /// Returns the finish time (the produced data's ready time).
    pub fn compute(&mut self, w: usize, dur: f64, ready: f64) -> f64 {
        let start = ready.max(self.compute_free[w]);
        let end = start + dur;
        self.compute_free[w] = end;
        self.comp_total[w] += dur;
        if dur > 0.0 {
            self.intervals[w].push(Interval { start, end, kind: StreamKind::Compute });
        }
        end
    }

    /// Schedule `dur` seconds of communication on worker `w`'s NIC stream.
    pub fn comm(&mut self, w: usize, dur: f64, ready: f64) -> f64 {
        let start = ready.max(self.comm_free[w]);
        let end = start + dur;
        self.comm_free[w] = end;
        self.comm_total[w] += dur;
        if dur > 0.0 {
            self.intervals[w].push(Interval { start, end, kind: StreamKind::Comm });
        }
        end
    }

    /// Current frontier of worker `w` (both streams drained).
    pub fn now(&self, w: usize) -> f64 {
        self.compute_free[w].max(self.comm_free[w])
    }

    /// Global synchronization: every stream advances to the max frontier
    /// (layer-wise barrier semantics). Returns the barrier time.
    pub fn barrier(&mut self) -> f64 {
        let t = (0..self.workers()).map(|w| self.now(w)).fold(0.0, f64::max);
        for w in 0..self.workers() {
            self.compute_free[w] = t;
            self.comm_free[w] = t;
        }
        t
    }

    /// Epoch end: the slowest worker's frontier.
    pub fn makespan(&self) -> f64 {
        (0..self.workers()).map(|w| self.now(w)).fold(0.0, f64::max)
    }

    pub fn comp_totals(&self) -> &[f64] {
        &self.comp_total
    }

    pub fn comm_totals(&self) -> &[f64] {
        &self.comm_total
    }

    pub fn intervals(&self, w: usize) -> &[Interval] {
        &self.intervals[w]
    }

    /// Fraction of `[t0, t1)` during which worker `w`'s compute stream is
    /// busy — the Fig 15 utilization proxy.
    pub fn compute_busy_fraction(&self, w: usize, t0: f64, t1: f64) -> f64 {
        let mut busy = 0.0;
        for iv in &self.intervals[w] {
            if iv.kind != StreamKind::Compute {
                continue;
            }
            let lo = iv.start.max(t0);
            let hi = iv.end.min(t1);
            if hi > lo {
                busy += hi - lo;
            }
        }
        (busy / (t1 - t0)).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_serializes_on_stream() {
        let mut s = EventSim::new(2);
        let t1 = s.compute(0, 1.0, 0.0);
        let t2 = s.compute(0, 1.0, 0.0); // stream busy until 1.0
        assert_eq!(t1, 1.0);
        assert_eq!(t2, 2.0);
    }

    #[test]
    fn comm_overlaps_compute() {
        let mut s = EventSim::new(1);
        let c = s.compute(0, 2.0, 0.0);
        let m = s.comm(0, 1.0, 0.0); // separate stream: overlaps
        assert_eq!(c, 2.0);
        assert_eq!(m, 1.0);
        assert_eq!(s.makespan(), 2.0);
    }

    #[test]
    fn ready_time_delays_start() {
        let mut s = EventSim::new(1);
        let t = s.compute(0, 0.5, 3.0);
        assert_eq!(t, 3.5);
    }

    #[test]
    fn barrier_aligns_workers() {
        let mut s = EventSim::new(2);
        s.compute(0, 5.0, 0.0);
        s.compute(1, 1.0, 0.0);
        let b = s.barrier();
        assert_eq!(b, 5.0);
        assert_eq!(s.compute(1, 1.0, 0.0), 6.0);
    }

    #[test]
    fn totals_track_durations() {
        let mut s = EventSim::new(2);
        s.compute(0, 1.5, 0.0);
        s.comm(0, 0.5, 0.0);
        s.comm(1, 2.0, 0.0);
        assert_eq!(s.comp_totals(), &[1.5, 0.0]);
        assert_eq!(s.comm_totals(), &[0.5, 2.0]);
    }

    #[test]
    fn busy_fraction_window() {
        let mut s = EventSim::new(1);
        s.compute(0, 1.0, 0.0);
        s.compute(0, 1.0, 3.0); // idle gap [1, 3)
        assert!((s.compute_busy_fraction(0, 0.0, 4.0) - 0.5).abs() < 1e-9);
        assert!((s.compute_busy_fraction(0, 0.0, 1.0) - 1.0).abs() < 1e-9);
        assert!(s.compute_busy_fraction(0, 1.0, 3.0) < 1e-9);
    }

    #[test]
    fn pipeline_beats_serial() {
        // the scheduling property IP relies on: overlapped comm hides
        // under compute, serial does not
        let chunks = 8;
        let (comp, comm) = (1.0, 0.8);
        let mut serial = EventSim::new(1);
        let mut ready = 0.0;
        for _ in 0..chunks {
            ready = serial.comm(0, comm, ready);
            ready = serial.compute(0, comp, ready);
        }
        let mut pipe = EventSim::new(1);
        let mut comm_done = vec![0.0; chunks];
        let mut r = 0.0;
        for c in 0..chunks {
            r = pipe.comm(0, comm, r);
            comm_done[c] = r;
        }
        let mut done = 0.0;
        for c in 0..chunks {
            done = pipe.compute(0, comp, comm_done[c]);
        }
        assert!(pipe.makespan() < serial.makespan());
        assert!((pipe.makespan() - (comm + chunks as f64 * comp)).abs() < 1e-9);
        let _ = done;
    }
}
