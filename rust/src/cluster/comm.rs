//! `Comm` — the per-run communicator every engine speaks through
//! (DESIGN.md §4.2). It owns the event sim and the network model, so no
//! engine constructs an `EventSim` or threads per-worker ready-time
//! vectors anymore: a collective's schedule point is the posting worker's
//! current stream frontier, and its completion times travel inside the
//! returned [`CommHandle`].
//!
//! The surface mirrors the executor seam (`submit` → `Ticket` →
//! `wait`): every collective has a **nonblocking `i*` variant** that
//! posts the NIC events immediately and returns a `CommHandle<T>`
//! carrying the moved data plus per-worker done-times, resolved on
//! `wait`. Because compute and comm are separate streams per worker,
//! compute submitted *after* a post never delays it — posting a
//! collective and computing past it is exactly the overlap the paper's
//! chunk pipelining (§4.2.2) exploits, now expressible at the API level.
//!
//! GNN tensor parallelism needs two collectives (paper §3.1):
//! * `gather` — dim-sliced `[V, D/N]` per worker → vertex-sliced
//!   `[V/N, D]` per worker (before NN ops, which need complete rows);
//! * `split`  — the inverse (before graph ops, which need dim slices).
//!
//! Plus `allreduce_sum` for parameter gradients, `allgather_rows` for
//! sharing precomputed attention scores, the SANCUS-style
//! `sequential_broadcast` pathology, and point-to-point `fetch_rows` /
//! `p2p` for DepComm-style neighbour pulls.
//!
//! Each collective selects its **algorithm** from the run's
//! [`CommTuning`]: naive all-to-all bursts vs pairwise-exchange rounds,
//! ring vs flat-tree allreduce. Numerics are identical across algorithms
//! (the data plane never depends on the algorithm) — only the modeled
//! times differ. A [`Topology`] of per-worker bandwidth multipliers
//! models straggler/hetero-NIC scenarios, and every byte and NIC-second
//! is attributed per collective kind in [`CommStats`], which
//! `metrics::EpochReport` surfaces for the Table-4 / `comm_scale`
//! breakdowns.

use std::ops::Range;
use std::sync::{Arc, Mutex};

use super::event::EventSim;
use crate::config::{AllReduceAlgo, AllToAllAlgo, CommTuning, NetModel, RunConfig};
use crate::tensor::Matrix;

/// Per-worker completion times of a collective.
pub type DoneTimes = Vec<f64>;

// ---- record mode (static comm-schedule capture, DESIGN.md §8) ----------

/// The round structure a collective committed to, captured in record mode
/// so `analysis::commlint` can check per-algorithm well-formedness without
/// replaying any timing.
#[derive(Clone, Debug, PartialEq)]
pub enum Rounds {
    /// Naive all-to-all: one burst of point-to-point messages
    /// `(src, dst, bytes)`, every entry a real (non-zero) message.
    Burst { msgs: Vec<(usize, usize, usize)> },
    /// XOR-paired pairwise exchange (power-of-two clusters): the
    /// unordered pairs that actually exchanged, per round.
    PairRounds { rounds: Vec<Vec<(usize, usize)>> },
    /// Round-robin offset schedule (non-power-of-two clusters).
    OffsetRounds { rounds: usize },
    /// Ring allreduce: every participant relays `2 (N-1)/N` of the block.
    Ring { participants: usize },
    /// Flat-tree allreduce: `fan_in` blocks into the root, `fan_out`
    /// copies back out.
    Tree { root: usize, fan_in: usize, fan_out: usize },
    /// Chunk-level pipeline piece: one uniform message per worker.
    Piece,
    /// SANCUS-style sequential broadcast, `senders` serialized rounds.
    Sequential { senders: usize },
    /// Point-to-point message (p2p / fetch_rows).
    P2p,
}

/// Sentinel `dep_step` in [`TraceEvent::Stage`] for transfers no compute
/// waits on (evictions) — mirrors `sched::staging::NO_DEP`.
pub const STAGE_NO_DEP: usize = usize::MAX;

/// A float reduction site the data plane performs. The fold order of its
/// terms is what the determinism prover (`analysis::audit`, DESIGN.md
/// §11.5) checks: every site must fold in canonical (ascending,
/// contiguous-from-zero) order, and the canonical sites must agree
/// across the config lattice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ReduceSite {
    /// Per-part gradient shares folded in part order
    /// (`parallel::common::allreduce_and_step`). For the TP family the
    /// parts are the canonical data partition (`CANON_DATA_PARTS`), which
    /// is what makes losses bit-identical across worker counts.
    GradSum,
    /// `iallreduce_sum`'s left fold over the per-worker input blocks, in
    /// worker index order.
    AllreduceChain,
    /// The chunked-aggregation partial drain of one `(round, chunk)`
    /// step (`parallel::common::PlanAgg::wait_into` drains passes in
    /// submission order). `step` numbers steps across the whole epoch.
    AggDrain { step: usize },
}

impl ReduceSite {
    pub fn name(self) -> &'static str {
        match self {
            ReduceSite::GradSum => "grad_sum",
            ReduceSite::AllreduceChain => "allreduce_chain",
            ReduceSite::AggDrain { .. } => "agg_drain",
        }
    }
}

/// One captured schedule event. `Post` carries the per-worker sent/recv
/// byte vectors — derived independently (row sums vs column sums of the
/// pair matrix) so Σ sent == Σ recv checks the schedule, not one
/// accumulator against itself. `Wait` marks the handle join point.
///
/// The remaining variants extend the trace past the comm plane so one
/// schedule covers all three planes the auditor checks (DESIGN.md §11.1):
/// `Submit`/`TicketWait` mirror the executor seam (`ExecutorPool::submit`
/// → `Ticket`/`ops::Pending` → `wait`), `StagePhase`/`Stage` mirror the
/// host-staging link ops of a `sched::StagingPlan`, and `Reduce` records
/// every float-reduction tree in its exact fold order.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    Post {
        seq: usize,
        kind: CommKind,
        algo: &'static str,
        workers: usize,
        sent: Vec<usize>,
        recv: Vec<usize>,
        rounds: Rounds,
    },
    Wait {
        seq: usize,
    },
    /// Compute plane: one executor job enqueued for aggregation step
    /// `step`. `seq` is a trace-global submission ordinal.
    Submit {
        seq: usize,
        step: usize,
    },
    /// Compute plane: the `Ticket`/`Pending` join of submission `seq`.
    /// Joins must drain in submission order (the executor's determinism
    /// contract) — the auditor rejects out-of-order drains.
    TicketWait {
        seq: usize,
    },
    /// Memory plane: opens one staged aggregation phase. `prefetch_cap`
    /// is the admission bound on unconsumed prefetched footprint
    /// (`budget - pinned - max_step_footprint`); the replay below resets
    /// at each phase header. Step ids in the following `Stage` events are
    /// phase-local (`0..steps`).
    StagePhase {
        budget: usize,
        pinned: usize,
        prefetch_cap: usize,
        steps: usize,
    },
    /// Memory plane: one staged link transfer (`sched::staging::LinkOp`).
    /// Fetches (`h2d`) carry the step whose compute waits on them
    /// (`dep_step > post_step` ⇒ prefetch); evictions carry
    /// [`STAGE_NO_DEP`].
    Stage {
        post_step: usize,
        dep_step: usize,
        panel: usize,
        bytes: usize,
        footprint: usize,
        h2d: bool,
    },
    /// A float reduction: `terms` in the exact order the engine folds
    /// them (DESIGN.md §11.5).
    Reduce {
        site: ReduceSite,
        terms: Vec<usize>,
    },
}

/// Shared capture buffer handed out by [`Comm::record`]. Cloning shares
/// the buffer (the `Comm` and its outstanding `CommHandle`s all append to
/// the same schedule).
#[derive(Clone, Debug, Default)]
pub struct CommTrace {
    events: Arc<Mutex<Vec<TraceEvent>>>,
}

impl CommTrace {
    /// Snapshot of the captured schedule so far.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().map(|e| e.clone()).unwrap_or_default()
    }

    /// Append one event. Public so the schedule mirror
    /// (`parallel::trace`) can record the compute and memory planes into
    /// the same buffer the communicator's collectives land in
    /// (DESIGN.md §11.1).
    pub fn push(&self, ev: TraceEvent) {
        if let Ok(mut e) = self.events.lock() {
            e.push(ev);
        }
    }
}

/// Collective kinds a `Comm` attributes bytes/seconds to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommKind {
    Split,
    Gather,
    AllreduceSum,
    AllgatherRows,
    SequentialBroadcast,
    FetchRows,
    PointToPoint,
}

impl CommKind {
    pub const ALL: [CommKind; 7] = [
        CommKind::Split,
        CommKind::Gather,
        CommKind::AllreduceSum,
        CommKind::AllgatherRows,
        CommKind::SequentialBroadcast,
        CommKind::FetchRows,
        CommKind::PointToPoint,
    ];

    pub fn name(self) -> &'static str {
        match self {
            CommKind::Split => "split",
            CommKind::Gather => "gather",
            CommKind::AllreduceSum => "allreduce_sum",
            CommKind::AllgatherRows => "allgather_rows",
            CommKind::SequentialBroadcast => "sequential_broadcast",
            CommKind::FetchRows => "fetch_rows",
            CommKind::PointToPoint => "p2p",
        }
    }

    fn index(self) -> usize {
        CommKind::ALL.iter().position(|k| *k == self).unwrap()
    }

    /// True when the timed implementation of this kind counts toward the
    /// elastic fault-detection ordinal ([`Comm::arm_fault`] fires at the
    /// k-th counted collective). Every cluster-wide timing core calls
    /// `note_collective`; the blocking point-to-point paths
    /// (`p2p`/`p2p_wire`/`fetch_rows`) self-join without a cluster round
    /// and do not count. The fault-window audit (DESIGN.md §11.4) uses
    /// this to prove no schedule window can silently drop an armed
    /// `FaultEvent`.
    pub fn is_detection_point(self) -> bool {
        !matches!(self, CommKind::FetchRows | CommKind::PointToPoint)
    }
}

/// Accumulated traffic of one collective kind.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KindStats {
    /// collective invocations of this kind
    pub ops: usize,
    /// bytes leaving any NIC under this kind
    pub bytes_sent: usize,
    /// bytes arriving at any NIC under this kind
    pub bytes_recv: usize,
    /// NIC-busy seconds charged across all workers
    pub secs: f64,
}

/// Per-collective-kind breakdown of an epoch's communication
/// (bytes + seconds), recorded by [`Comm`] and surfaced through
/// `metrics::EpochReport`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    per_kind: [KindStats; 7],
}

impl CommStats {
    fn record(&mut self, kind: CommKind, sent: usize, recv: usize, secs: f64) {
        let s = &mut self.per_kind[kind.index()];
        s.ops += 1;
        s.bytes_sent += sent;
        s.bytes_recv += recv;
        s.secs += secs;
    }

    pub fn kind(&self, kind: CommKind) -> &KindStats {
        &self.per_kind[kind.index()]
    }

    pub fn total_sent(&self) -> usize {
        self.per_kind.iter().map(|s| s.bytes_sent).sum()
    }

    pub fn total_secs(&self) -> f64 {
        self.per_kind.iter().map(|s| s.secs).sum()
    }

    /// Non-empty kinds in declaration order: `(name, stats)`.
    pub fn breakdown(&self) -> Vec<(&'static str, KindStats)> {
        CommKind::ALL
            .iter()
            .filter(|k| self.per_kind[k.index()].ops > 0)
            .map(|k| (k.name(), self.per_kind[k.index()]))
            .collect()
    }
}

/// Per-worker NIC topology: bandwidth multipliers relative to the
/// `NetModel` baseline (`0.5` = half bandwidth, i.e. a straggler NIC).
/// Latency is uniform; only wire time scales.
#[derive(Clone, Debug)]
pub struct Topology {
    bw_scale: Vec<f64>,
}

impl Topology {
    pub fn uniform(workers: usize) -> Topology {
        Topology { bw_scale: vec![1.0; workers] }
    }

    /// Pad `scale` (with 1.0) to `workers` entries. A list *longer* than
    /// the cluster is rejected: silently dropping the tail would ignore
    /// straggler entries the user asked for (the classic foot-gun of
    /// tuning `comm.bw_scale` for 8 workers, then running with 4).
    pub fn with_bw_scale(workers: usize, scale: &[f64]) -> crate::Result<Topology> {
        anyhow::ensure!(
            scale.len() <= workers,
            "comm.bw_scale has {} entries but the cluster has {} workers — \
             trim the list or raise --workers (shorter lists pad with 1.0)",
            scale.len(),
            workers
        );
        let mut bw_scale = vec![1.0; workers];
        for (dst, s) in bw_scale.iter_mut().zip(scale) {
            *dst = *s;
        }
        Ok(Topology { bw_scale })
    }

    pub fn bw_scale(&self, w: usize) -> f64 {
        self.bw_scale[w]
    }

    fn wire_secs(&self, net: &NetModel, w: usize, bytes: usize) -> f64 {
        net.wire_secs(bytes) / self.bw_scale[w].max(1e-9)
    }

    fn msg_secs(&self, net: &NetModel, w: usize, bytes: usize) -> f64 {
        net.latency_us * 1e-6 + self.wire_secs(net, w, bytes)
    }
}

/// A posted (in-flight) collective: the moved data plus the per-worker
/// completion times, resolved on [`CommHandle::wait`]. Every posted
/// handle must be joined exactly once: besides the `#[must_use]` lint,
/// debug builds carry a runtime tripwire (DESIGN.md §11.1) — dropping a
/// handle without waiting panics, so a schedule that forfeits done-times
/// cannot survive the test suite. (The NIC accounting is never at risk:
/// the events are posted at call time.)
#[must_use = "a posted collective's done-times are only reachable through wait()"]
pub struct CommHandle<T> {
    /// `Some` until `wait` takes it; the drop guard keys off this.
    data: Option<T>,
    done: DoneTimes,
    /// record mode only: the trace to append the `Wait` event to, and the
    /// sequence number of this handle's `Post`.
    rec: Option<(CommTrace, usize)>,
}

impl<T> CommHandle<T> {
    /// Resolve the collective: data plus per-worker done-times.
    pub fn wait(mut self) -> (T, DoneTimes) {
        if let Some((trace, seq)) = &self.rec {
            trace.push(TraceEvent::Wait { seq: *seq });
        }
        let Some(data) = self.data.take() else {
            unreachable!("wait() consumes the handle and is the only taker")
        };
        (data, std::mem::take(&mut self.done))
    }

    /// Resolve and reduce the done-times to the slowest participant
    /// (barrier-style join).
    pub fn wait_barrier(self) -> (T, f64) {
        let (data, done) = self.wait();
        let t = done.iter().copied().fold(0.0, f64::max);
        (data, t)
    }

    /// Peek at the per-worker done-times without consuming the handle.
    pub fn done(&self) -> &DoneTimes {
        &self.done
    }
}

impl<T> Drop for CommHandle<T> {
    /// Debug-build drop guard (DESIGN.md §11.1): a posted collective
    /// dropped unwaited is a schedule defect — its done-times never join
    /// the timeline. Upgrade the `#[must_use]` lint to a runtime panic in
    /// tests; release builds and unwinding threads stay silent.
    fn drop(&mut self) {
        if cfg!(debug_assertions) && self.data.is_some() && !std::thread::panicking() {
            panic!(
                "CommHandle dropped without wait(): a posted collective must be \
                 joined exactly once (join it with wait()/wait_barrier())"
            );
        }
    }
}

/// The communicator: owns the run's `EventSim`, network model, algorithm
/// selection and topology; every engine's comm *and* compute events flow
/// through it.
#[derive(Clone, Debug)]
pub struct Comm {
    sim: EventSim,
    net: NetModel,
    all_to_all: AllToAllAlgo,
    allreduce: AllReduceAlgo,
    topo: Topology,
    stats: CommStats,
    /// sent-side bytes per worker (feeds `WorkerLoad::comm_bytes`)
    bytes_per_worker: Vec<usize>,
    /// record mode (DESIGN.md §8): capture the collective schedule instead
    /// of advancing the `EventSim`
    trace: Option<CommTrace>,
    next_seq: usize,
    /// seq of the most recent `Post`, consumed by the next handle wrap
    pending_seq: Option<usize>,
    /// armed modeled fault: `(worker, collective ordinal)` at which the
    /// worker "dies" (DESIGN.md §9.1)
    fault_arm: Option<(usize, usize)>,
    /// collectives timed so far (record mode never counts)
    collectives_seen: usize,
    /// the recorded loss, once the armed collective fires
    fault: Option<super::fault::FaultEvent>,
    /// bytes per f32 element on the wire for *feature-panel* collectives
    /// (split/gather/allgather/fetch and their byte probes): 4, or 2 with
    /// `comm.bf16_wire` (DESIGN.md §5.3). Gradient allreduce and p2p
    /// always ship f32.
    wire_bpe: usize,
}

impl Comm {
    pub fn new(workers: usize, net: NetModel, tuning: &CommTuning) -> crate::Result<Comm> {
        Ok(Comm {
            sim: EventSim::new(workers),
            net,
            all_to_all: tuning.all_to_all,
            allreduce: tuning.allreduce,
            topo: Topology::with_bw_scale(workers, &tuning.bw_scale)?,
            stats: CommStats::default(),
            bytes_per_worker: vec![0; workers],
            trace: None,
            next_seq: 0,
            pending_seq: None,
            fault_arm: None,
            collectives_seen: 0,
            fault: None,
            wire_bpe: if tuning.bf16_wire { 2 } else { 4 },
        })
    }

    /// Bytes per f32 element the feature-panel collectives charge (4, or
    /// 2 under `comm.bf16_wire`).
    pub fn wire_bpe(&self) -> usize {
        self.wire_bpe
    }

    /// Wire bytes of an `f32_bytes`-sized f32 panel under the configured
    /// wire dtype.
    fn wire(&self, f32_bytes: usize) -> usize {
        f32_bytes / 4 * self.wire_bpe
    }

    /// The communicator a run configuration asks for.
    pub fn for_run(cfg: &RunConfig) -> crate::Result<Comm> {
        Comm::new(cfg.workers, cfg.net, &cfg.comm)
    }

    /// The communicator for epoch `epoch` of `cfg`: [`Comm::for_run`],
    /// plus the `[fault]` plan armed when this is the kill epoch — the
    /// modeled loss of `fault.kill_worker` fires at the epoch's first
    /// collective and is recorded as a [`super::fault::FaultEvent`]
    /// (DESIGN.md §9.1). Engines keep computing (the data plane is
    /// host-side and the epoch will be discarded); the elastic driver
    /// reads the event off the epoch report.
    pub fn for_epoch(cfg: &RunConfig, epoch: usize) -> crate::Result<Comm> {
        let mut comm = Comm::for_run(cfg)?;
        if let (Some(w), Some(e)) = (cfg.fault.kill_worker, cfg.fault.kill_epoch) {
            if e == epoch {
                comm.arm_fault(w, 1);
            }
        }
        Ok(comm)
    }

    /// Arm a modeled loss of worker `w`, detected at the
    /// `at_collective`-th collective (1-based) timed by this
    /// communicator.
    pub fn arm_fault(&mut self, w: usize, at_collective: usize) {
        self.fault_arm = Some((w, at_collective.max(1)));
    }

    /// The recorded worker loss, if the armed collective has fired.
    pub fn fault_event(&self) -> Option<&super::fault::FaultEvent> {
        self.fault.as_ref()
    }

    /// Count one timed collective and record the armed fault when its
    /// ordinal comes up. Called from the timing cores *after* the sim
    /// advanced, so `at_secs` is the makespan the partial epoch wasted.
    fn note_collective(&mut self) {
        self.collectives_seen += 1;
        if let Some((w, at)) = self.fault_arm {
            if self.fault.is_none() && self.collectives_seen >= at {
                self.fault = Some(super::fault::FaultEvent {
                    worker: w,
                    at_collective: self.collectives_seen,
                    at_secs: self.sim.makespan(),
                });
            }
        }
    }

    pub fn workers(&self) -> usize {
        self.sim.workers()
    }

    pub fn sim(&self) -> &EventSim {
        &self.sim
    }

    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    pub fn bytes_per_worker(&self) -> &[usize] {
        &self.bytes_per_worker
    }

    // ---- record mode ----------------------------------------------------

    /// Switch this communicator into **record mode** (DESIGN.md §8):
    /// every collective posted from here on is captured as a
    /// [`TraceEvent`] behind the unchanged API — same pair matrices, same
    /// algorithm dispatch, same stats attribution — but **no `EventSim`
    /// event is scheduled** and all done-times are zero. The returned
    /// trace is the capture buffer; `analysis::commlint` checks it.
    pub fn record(&mut self) -> CommTrace {
        let trace = CommTrace::default();
        self.trace = Some(trace.clone());
        trace
    }

    /// True when [`Comm::record`] was called: collectives capture their
    /// schedule instead of advancing the event sim.
    pub fn recording(&self) -> bool {
        self.trace.is_some()
    }

    /// Append a `Post` event and remember its seq for the handle about to
    /// be wrapped. No-op outside record mode.
    fn push_post(
        &mut self,
        kind: CommKind,
        algo: &'static str,
        sent: Vec<usize>,
        recv: Vec<usize>,
        rounds: Rounds,
    ) {
        if let Some(trace) = &self.trace {
            let seq = self.next_seq;
            self.next_seq += 1;
            trace.push(TraceEvent::Post {
                seq,
                kind,
                algo,
                workers: self.workers(),
                sent,
                recv,
                rounds,
            });
            self.pending_seq = Some(seq);
        }
    }

    /// Wrap collective results in a `CommHandle`, attaching the pending
    /// `Post` seq so the handle's `wait` lands a matching `Wait` event.
    fn wrap<T>(&mut self, data: T, done: DoneTimes) -> CommHandle<T> {
        let rec = match (&self.trace, self.pending_seq.take()) {
            (Some(trace), Some(seq)) => Some((trace.clone(), seq)),
            _ => None,
        };
        CommHandle { data: Some(data), done, rec }
    }

    // ---- compute-stream passthrough ------------------------------------
    // (the sim is owned here; engines schedule device work through the
    // same object so comm and compute share one timeline)

    /// Schedule `dur` seconds of compute on worker `w`, not before
    /// `ready`. Returns the finish time.
    pub fn compute(&mut self, w: usize, dur: f64, ready: f64) -> f64 {
        self.sim.compute(w, dur, ready)
    }

    /// Current frontier of worker `w` (both streams drained).
    pub fn now(&self, w: usize) -> f64 {
        self.sim.now(w)
    }

    /// Global synchronization of every stream (BSP phase boundary).
    pub fn barrier(&mut self) -> f64 {
        self.sim.barrier()
    }

    /// The slowest worker's frontier.
    pub fn makespan(&self) -> f64 {
        self.sim.makespan()
    }

    // ---- point-to-point -------------------------------------------------

    /// Charge one message of `bytes` to worker `w`'s NIC at its current
    /// frontier (DepComm-style neighbour/feature pull accounting).
    /// Returns the completion time.
    pub fn p2p(&mut self, w: usize, bytes: usize) -> f64 {
        if self.record_p2p(w, bytes) {
            return 0.0;
        }
        let dur = self.topo.msg_secs(&self.net, w, bytes);
        let ready = self.sim.now(w);
        let done = self.sim.comm(w, dur, ready);
        self.stats.record(CommKind::PointToPoint, bytes, bytes, dur);
        self.bytes_per_worker[w] += bytes;
        done
    }

    /// Like [`Comm::p2p`] but wire time only — no per-message latency.
    /// For bulk accounting of data that is already streaming (e.g. the
    /// GAT alpha share, where the bytes ride existing connections).
    pub fn p2p_wire(&mut self, w: usize, bytes: usize) -> f64 {
        if self.record_p2p(w, bytes) {
            return 0.0;
        }
        let dur = self.topo.wire_secs(&self.net, w, bytes);
        let ready = self.sim.now(w);
        let done = self.sim.comm(w, dur, ready);
        self.stats.record(CommKind::PointToPoint, bytes, bytes, dur);
        self.bytes_per_worker[w] += bytes;
        done
    }

    /// Record-mode p2p capture: a blocking point-to-point is its own join
    /// point, so the `Wait` lands immediately after the `Post`. Returns
    /// false outside record mode.
    fn record_p2p(&mut self, w: usize, bytes: usize) -> bool {
        if self.trace.is_none() {
            return false;
        }
        let n = self.workers();
        let mut vol = vec![0usize; n];
        vol[w] = bytes;
        self.push_post(CommKind::PointToPoint, "p2p", vol.clone(), vol, Rounds::P2p);
        if let (Some(trace), Some(seq)) = (&self.trace, self.pending_seq.take()) {
            trace.push(TraceEvent::Wait { seq });
        }
        self.stats.record(CommKind::PointToPoint, bytes, bytes, 0.0);
        self.bytes_per_worker[w] += bytes;
        true
    }

    /// Point-to-point fetch of specific rows from an owner worker
    /// (DepComm neighbour pull). Returns the fetched rows and the
    /// completion time (both NICs released).
    pub fn fetch_rows(
        &mut self,
        owner_data: &Matrix,
        owner_base: usize,
        rows: &[u32],
        owner: usize,
        requester: usize,
    ) -> (Matrix, f64) {
        let (block, done) = self
            .ifetch_rows(owner_data, owner_base, rows, owner, requester)
            .wait();
        let t = done[owner].max(done[requester]);
        (block, t)
    }

    /// Nonblocking [`Comm::fetch_rows`]: done-times carry the owner's and
    /// requester's completion (other entries are those workers' current
    /// frontiers).
    pub fn ifetch_rows(
        &mut self,
        owner_data: &Matrix,
        owner_base: usize,
        rows: &[u32],
        owner: usize,
        requester: usize,
    ) -> CommHandle<Matrix> {
        let local: Vec<u32> = rows.iter().map(|&r| r - owner_base as u32).collect();
        let block = owner_data.gather_rows(&local);
        let bytes = self.wire(block.bytes());
        if self.trace.is_some() {
            let n = self.workers();
            let mut sent = vec![0usize; n];
            let mut recv = vec![0usize; n];
            sent[owner] = bytes;
            recv[requester] = bytes;
            self.push_post(CommKind::FetchRows, "p2p", sent, recv, Rounds::P2p);
            self.stats.record(CommKind::FetchRows, bytes, bytes, 0.0);
            self.bytes_per_worker[owner] += bytes;
            let done = vec![0.0; n];
            return self.wrap(block, done);
        }
        let dur_o = self.topo.msg_secs(&self.net, owner, bytes);
        let dur_r = self.topo.msg_secs(&self.net, requester, bytes);
        let ready = self.sim.now(owner).max(self.sim.now(requester));
        // occupies both NICs; the requester cannot finish receiving
        // before the owner started sending
        let t_owner = self.sim.comm(owner, dur_o, ready);
        let t_req = self.sim.comm(requester, dur_r, ready.max(t_owner - dur_o));
        self.stats.record(CommKind::FetchRows, bytes, bytes, dur_o + dur_r);
        self.bytes_per_worker[owner] += bytes;
        let mut done: DoneTimes = (0..self.workers()).map(|w| self.sim.now(w)).collect();
        done[owner] = t_owner;
        done[requester] = t_req.max(t_owner);
        self.wrap(block, done)
    }

    // ---- split / gather (the TP embedding collectives) ------------------

    /// `split`: vertex-sliced full-width inputs → dim-sliced outputs.
    ///
    /// `inputs[i]` holds rows `row_parts[i]` with full width `D`; output
    /// `j` holds all `V` rows restricted to columns `dim_parts[j]`.
    pub fn split(
        &mut self,
        inputs: &[Matrix],
        row_parts: &[Range<usize>],
        dim_parts: &[Range<usize>],
    ) -> (Vec<Matrix>, DoneTimes) {
        self.isplit(inputs, row_parts, dim_parts).wait()
    }

    /// Nonblocking [`Comm::split`].
    pub fn isplit(
        &mut self,
        inputs: &[Matrix],
        row_parts: &[Range<usize>],
        dim_parts: &[Range<usize>],
    ) -> CommHandle<Vec<Matrix>> {
        let n = inputs.len();
        let v: usize = row_parts.iter().map(Range::len).sum();
        let mut outs: Vec<Matrix> =
            dim_parts.iter().map(|d| Matrix::zeros(v, d.len())).collect();
        let mut pair = vec![vec![0usize; n]; n];
        for i in 0..n {
            for (j, dp) in dim_parts.iter().enumerate() {
                let block = inputs[i].slice_cols(dp.clone());
                if i != j {
                    pair[i][j] = self.wire(block.bytes());
                }
                outs[j].write_rows(row_parts[i].start, &block);
            }
        }
        let done = self.all_to_all(&pair, CommKind::Split);
        self.wrap(outs, done)
    }

    /// `gather`: dim-sliced inputs → vertex-sliced full-width outputs.
    pub fn gather(
        &mut self,
        inputs: &[Matrix],
        row_parts: &[Range<usize>],
        dim_parts: &[Range<usize>],
    ) -> (Vec<Matrix>, DoneTimes) {
        self.igather(inputs, row_parts, dim_parts).wait()
    }

    /// Nonblocking [`Comm::gather`].
    pub fn igather(
        &mut self,
        inputs: &[Matrix],
        row_parts: &[Range<usize>],
        dim_parts: &[Range<usize>],
    ) -> CommHandle<Vec<Matrix>> {
        let n = inputs.len();
        let d: usize = dim_parts.iter().map(Range::len).sum();
        let mut outs: Vec<Matrix> =
            row_parts.iter().map(|r| Matrix::zeros(r.len(), d)).collect();
        let mut pair = vec![vec![0usize; n]; n];
        for (j, dp) in dim_parts.iter().enumerate() {
            for (i, rp) in row_parts.iter().enumerate() {
                let block = inputs[j].slice_rows(rp.clone());
                if i != j {
                    pair[j][i] = self.wire(block.bytes());
                }
                outs[i].write_cols(dp.start, &block);
            }
        }
        let done = self.all_to_all(&pair, CommKind::Gather);
        self.wrap(outs, done)
    }

    /// Schedule-only [`Comm::isplit`]: the same pair matrix — worker `i`
    /// sends its `row_parts[i]` rows restricted to `dim_parts[j]` columns
    /// to worker `j`, f32 elements — without allocating or moving any
    /// matrix data. The static verifier's split probe (DESIGN.md §8).
    pub fn isplit_bytes(
        &mut self,
        row_parts: &[Range<usize>],
        dim_parts: &[Range<usize>],
    ) -> CommHandle<()> {
        let n = row_parts.len();
        let mut pair = vec![vec![0usize; n]; n];
        for (i, rp) in row_parts.iter().enumerate() {
            for (j, dp) in dim_parts.iter().enumerate() {
                if i != j {
                    pair[i][j] = rp.len() * dp.len() * self.wire_bpe;
                }
            }
        }
        let done = self.all_to_all(&pair, CommKind::Split);
        self.wrap((), done)
    }

    /// Schedule-only [`Comm::igather`]: worker `j` sends rows
    /// `row_parts[i]` of its `dim_parts[j]`-wide slice to worker `i`.
    pub fn igather_bytes(
        &mut self,
        row_parts: &[Range<usize>],
        dim_parts: &[Range<usize>],
    ) -> CommHandle<()> {
        let n = row_parts.len();
        let mut pair = vec![vec![0usize; n]; n];
        for (j, dp) in dim_parts.iter().enumerate() {
            for (i, rp) in row_parts.iter().enumerate() {
                if i != j {
                    pair[j][i] = rp.len() * dp.len() * self.wire_bpe;
                }
            }
        }
        let done = self.all_to_all(&pair, CommKind::Gather);
        self.wrap((), done)
    }

    /// Schedule-only [`Comm::iallgather_rows`]: worker `i` broadcasts a
    /// block of `block_bytes[i]` *f32* bytes to every peer (wire-dtype
    /// scaling is applied here, matching the data-plane entry).
    pub fn iallgather_bytes(&mut self, block_bytes: &[usize]) -> CommHandle<()> {
        let n = block_bytes.len();
        let mut pair = vec![vec![0usize; n]; n];
        for (i, &b) in block_bytes.iter().enumerate() {
            let b = self.wire(b);
            for (j, pij) in pair[i].iter_mut().enumerate() {
                if i != j {
                    *pij = b;
                }
            }
        }
        let done = self.all_to_all(&pair, CommKind::AllgatherRows);
        self.wrap((), done)
    }

    // ---- pipelined chunk pieces (paper §4.2.2) --------------------------

    /// Post the chunk-level pieces of a segmented split: piece `k`
    /// charges one message of `bytes_per_piece[k]` *f32* bytes (wire
    /// dtype applied here) to every worker's NIC, pieces queueing
    /// back-to-back on the comm stream. Returns one handle per piece so
    /// the engine can start chunk `k`'s aggregation the moment piece `k`
    /// lands while later pieces are still in flight — overlap via posted
    /// handles instead of hand-merged ready vectors.
    pub fn isplit_pieces(&mut self, bytes_per_piece: &[usize]) -> Vec<CommHandle<()>> {
        bytes_per_piece
            .iter()
            .map(|&b| self.piece(b, CommKind::Split))
            .collect()
    }

    /// Post one chunk-level gather piece (the inverse direction), at
    /// every worker's current frontier.
    pub fn igather_piece(&mut self, bytes: usize) -> CommHandle<()> {
        self.piece(bytes, CommKind::Gather)
    }

    fn piece(&mut self, f32_bytes: usize, kind: CommKind) -> CommHandle<()> {
        let bytes = self.wire(f32_bytes);
        let n = self.workers();
        if self.trace.is_some() {
            let vol = vec![bytes; n];
            self.push_post(kind, "piece", vol.clone(), vol, Rounds::Piece);
            self.stats.record(kind, bytes * n, bytes * n, 0.0);
            for b in self.bytes_per_worker.iter_mut() {
                *b += bytes;
            }
            return self.wrap((), vec![0.0; n]);
        }
        let mut done = vec![0.0; n];
        let mut secs = 0.0;
        for w in 0..n {
            let dur = self.topo.msg_secs(&self.net, w, bytes);
            let ready = self.sim.now(w);
            done[w] = self.sim.comm(w, dur, ready);
            secs += dur;
            self.bytes_per_worker[w] += bytes;
        }
        self.stats.record(kind, bytes * n, bytes * n, secs);
        self.note_collective();
        self.wrap((), done)
    }

    // ---- allreduce ------------------------------------------------------

    /// Allreduce (sum) over per-worker equally-shaped tensors, e.g.
    /// parameter gradients. Algorithm per [`CommTuning::allreduce`]:
    /// ring (`2 (N-1)/N · bytes` wire per worker) or flat tree (root
    /// serializes `N-1` receives, then re-broadcasts).
    pub fn allreduce_sum(&mut self, inputs: &[Matrix]) -> (Matrix, DoneTimes) {
        self.iallreduce_sum(inputs).wait()
    }

    /// Nonblocking [`Comm::allreduce_sum`].
    pub fn iallreduce_sum(&mut self, inputs: &[Matrix]) -> CommHandle<Matrix> {
        let n = inputs.len();
        let mut sum = inputs[0].clone();
        for m in &inputs[1..] {
            sum.add_assign(m);
        }
        let bytes = sum.bytes();
        if n <= 1 {
            let done = vec![self.sim.now(0)];
            return self.wrap(sum, done);
        }
        let done = self.allreduce_times(n, bytes);
        self.wrap(sum, done)
    }

    /// Schedule-only allreduce over the full cluster: identical algorithm
    /// dispatch and byte accounting as [`Comm::iallreduce_sum`] without
    /// moving any data. The static verifier's entry point (DESIGN.md §8);
    /// also usable as a pure cost-model probe.
    pub fn iallreduce_bytes(&mut self, bytes: usize) -> CommHandle<()> {
        let n = self.workers();
        if n <= 1 {
            let done = vec![self.sim.now(0)];
            return self.wrap((), done);
        }
        let done = self.allreduce_times(n, bytes);
        self.wrap((), done)
    }

    /// Allreduce timing core shared by the data-plane and byte-only
    /// entries: in record mode, capture the algorithm's round structure
    /// and per-worker volumes instead of advancing the sim.
    fn allreduce_times(&mut self, n: usize, bytes: usize) -> DoneTimes {
        if self.trace.is_some() {
            let (algo, sent, rounds) = match self.allreduce {
                AllReduceAlgo::Ring => {
                    let share = 2.0 * (n - 1) as f64 / n as f64;
                    let b = (share * bytes as f64) as usize;
                    ("ring", vec![b; n], Rounds::Ring { participants: n })
                }
                AllReduceAlgo::FlatTree => {
                    let mut sent = vec![bytes; n];
                    sent[0] = (n - 1) * bytes;
                    let rounds = Rounds::Tree { root: 0, fan_in: n - 1, fan_out: n - 1 };
                    ("flat_tree", sent, rounds)
                }
            };
            for (w, b) in sent.iter().enumerate() {
                self.bytes_per_worker[w] += b;
            }
            let total: usize = sent.iter().sum();
            // both algorithms move symmetric volumes: every sent byte of
            // the reduce phase is a received byte of the broadcast phase
            self.push_post(CommKind::AllreduceSum, algo, sent.clone(), sent, rounds);
            self.stats.record(CommKind::AllreduceSum, total, total, 0.0);
            return vec![0.0; n];
        }
        let ready: Vec<f64> = (0..n).map(|w| self.sim.now(w)).collect();
        let done = match self.allreduce {
            AllReduceAlgo::Ring => self.allreduce_ring(n, bytes, &ready),
            AllReduceAlgo::FlatTree => self.allreduce_flat_tree(n, bytes, &ready),
        };
        self.note_collective();
        done
    }

    fn allreduce_ring(&mut self, n: usize, bytes: usize, ready: &[f64]) -> DoneTimes {
        let mut done = vec![0.0; n];
        let mut secs = 0.0;
        let mut sent_total = 0usize;
        let share = 2.0 * (n - 1) as f64 / n as f64;
        for w in 0..n {
            let wire = share * self.topo.wire_secs(&self.net, w, bytes)
                + 2.0 * (n - 1) as f64 * self.net.latency_us * 1e-6;
            done[w] = self.sim.comm(w, wire, ready[w]);
            secs += wire;
            let b = (share * bytes as f64) as usize;
            self.bytes_per_worker[w] += b;
            sent_total += b;
        }
        // ring steps synchronize all participants
        let t = done.iter().copied().fold(0.0, f64::max);
        done.iter_mut().for_each(|d| *d = t);
        // stats record the sum of the per-worker credits, so the
        // per-worker/total invariant holds even when the share truncates
        self.stats.record(CommKind::AllreduceSum, sent_total, sent_total, secs);
        done
    }

    fn allreduce_flat_tree(&mut self, n: usize, bytes: usize, ready: &[f64]) -> DoneTimes {
        let lat = self.net.latency_us * 1e-6;
        let mut secs = 0.0;
        // up: every non-root sends its block; the root's NIC serializes
        // the N-1 receives
        let mut up = 0.0f64;
        for w in 1..n {
            let dur = self.topo.msg_secs(&self.net, w, bytes);
            up = up.max(self.sim.comm(w, dur, ready[w]));
            secs += dur;
        }
        let root_up =
            (n - 1) as f64 * (self.topo.wire_secs(&self.net, 0, bytes) + lat);
        up = up.max(self.sim.comm(0, root_up, ready[0]));
        secs += root_up;
        // down: the root re-broadcasts the reduced block to everyone
        let root_down = root_up; // same N-1 messages, outbound
        let mut down = self.sim.comm(0, root_down, up);
        secs += root_down;
        for w in 1..n {
            let dur = self.topo.msg_secs(&self.net, w, bytes);
            down = down.max(self.sim.comm(w, dur, up));
            secs += dur;
        }
        // sent side: the root re-broadcasts N-1 copies, everyone else
        // sends its single block up (receives are tracked in the stats)
        for (w, b) in self.bytes_per_worker.iter_mut().enumerate().take(n) {
            *b += if w == 0 { (n - 1) * bytes } else { bytes };
        }
        // up: N-1 blocks into the root; down: N-1 copies out of it
        let total = (2 * (n - 1)) * bytes;
        self.stats.record(CommKind::AllreduceSum, total, total, secs);
        // the tree synchronizes everyone at the final broadcast
        vec![down; n]
    }

    // ---- allgather ------------------------------------------------------

    /// All-gather of per-worker row blocks into the full matrix
    /// everywhere (sharing precomputed attention scores, paper §4.1.1).
    /// Block `i` lands at the global rows `row_parts[i]` describes.
    pub fn allgather_rows(
        &mut self,
        inputs: &[Matrix],
        row_parts: &[Range<usize>],
    ) -> (Matrix, DoneTimes) {
        self.iallgather_rows(inputs, row_parts).wait()
    }

    /// Nonblocking [`Comm::allgather_rows`].
    pub fn iallgather_rows(
        &mut self,
        inputs: &[Matrix],
        row_parts: &[Range<usize>],
    ) -> CommHandle<Matrix> {
        let n = inputs.len();
        debug_assert_eq!(row_parts.len(), n);
        let v: usize = row_parts.iter().map(Range::len).sum();
        let d = inputs[0].cols();
        let mut full = Matrix::zeros(v, d);
        let mut pair = vec![vec![0usize; n]; n];
        for (i, rp) in row_parts.iter().enumerate() {
            debug_assert_eq!(inputs[i].rows(), rp.len());
            full.write_rows(rp.start, &inputs[i]);
            let b = self.wire(inputs[i].bytes());
            for (j, pij) in pair[i].iter_mut().enumerate() {
                if i != j {
                    *pij = b;
                }
            }
        }
        let done = self.all_to_all(&pair, CommKind::AllgatherRows);
        self.wrap(full, done)
    }

    // ---- sequential broadcast (SANCUS pathology) ------------------------

    /// SANCUS-style *sequential* broadcast: worker after worker
    /// broadcasts its full local block to everyone, each waiting for the
    /// previous broadcast — the serialization the paper blames for
    /// Sancus's poor scaling (§5.2). Sender/receiver costs are
    /// asymmetric; the round still ends at the slowest participant.
    pub fn sequential_broadcast(&mut self, inputs: &[Matrix]) -> (Matrix, DoneTimes) {
        self.isequential_broadcast(inputs).wait()
    }

    /// Nonblocking [`Comm::sequential_broadcast`].
    pub fn isequential_broadcast(&mut self, inputs: &[Matrix]) -> CommHandle<Matrix> {
        let n = inputs.len();
        let full = Matrix::concat_rows(inputs);
        if self.trace.is_some() {
            let peers = n.saturating_sub(1);
            let sent: Vec<usize> = inputs.iter().map(|m| m.bytes() * peers).collect();
            let total_in: usize = inputs.iter().map(Matrix::bytes).sum();
            let recv: Vec<usize> =
                inputs.iter().map(|m| total_in - m.bytes()).collect();
            let sent_total: usize = sent.iter().sum();
            for (w, b) in sent.iter().enumerate() {
                self.bytes_per_worker[w] += b;
            }
            self.push_post(
                CommKind::SequentialBroadcast,
                "sequential",
                sent,
                recv,
                Rounds::Sequential { senders: n },
            );
            self.stats.record(CommKind::SequentialBroadcast, sent_total, sent_total, 0.0);
            return self.wrap(full, vec![0.0; n]);
        }
        let lat = self.net.latency_us * 1e-6;
        let mut frontier = (0..n).map(|w| self.sim.now(w)).fold(0.0, f64::max);
        let mut secs = 0.0;
        let mut sent_total = 0usize;
        for s in 0..n {
            let peers = n.saturating_sub(1);
            let bytes = inputs[s].bytes();
            let send_dur =
                self.topo.wire_secs(&self.net, s, bytes * peers) + lat * peers as f64;
            let mut next = frontier;
            for w in 0..n {
                let dur = if w == s {
                    send_dur
                } else {
                    self.topo.msg_secs(&self.net, w, bytes)
                };
                let d = self.sim.comm(w, dur, frontier);
                secs += dur;
                next = next.max(d);
            }
            self.bytes_per_worker[s] += bytes * peers;
            sent_total += bytes * peers;
            frontier = next;
        }
        self.stats
            .record(CommKind::SequentialBroadcast, sent_total, sent_total, secs);
        self.note_collective();
        self.wrap(full, vec![frontier; n])
    }

    /// Schedule-only [`Comm::isequential_broadcast`]: worker `s`
    /// broadcasts a block of `block_bytes[s]` to every peer, senders
    /// serialized — identical algorithm dispatch and byte accounting as
    /// the data-plane entry without moving any matrix. The static
    /// verifier's and the planner's cost probe for the Sancus-style
    /// refresh (DESIGN.md §8, §10).
    pub fn isequential_broadcast_bytes(&mut self, block_bytes: &[usize]) -> CommHandle<()> {
        let n = block_bytes.len();
        let peers = n.saturating_sub(1);
        if self.trace.is_some() {
            let sent: Vec<usize> = block_bytes.iter().map(|b| b * peers).collect();
            let total_in: usize = block_bytes.iter().sum();
            let recv: Vec<usize> = block_bytes.iter().map(|b| total_in - b).collect();
            let sent_total: usize = sent.iter().sum();
            for (w, b) in sent.iter().enumerate() {
                self.bytes_per_worker[w] += b;
            }
            self.push_post(
                CommKind::SequentialBroadcast,
                "sequential",
                sent,
                recv,
                Rounds::Sequential { senders: n },
            );
            self.stats.record(CommKind::SequentialBroadcast, sent_total, sent_total, 0.0);
            return self.wrap((), vec![0.0; n]);
        }
        let lat = self.net.latency_us * 1e-6;
        let mut frontier = (0..n).map(|w| self.sim.now(w)).fold(0.0, f64::max);
        let mut secs = 0.0;
        let mut sent_total = 0usize;
        for (s, &bytes) in block_bytes.iter().enumerate() {
            let send_dur =
                self.topo.wire_secs(&self.net, s, bytes * peers) + lat * peers as f64;
            let mut next = frontier;
            for w in 0..n {
                let dur = if w == s {
                    send_dur
                } else {
                    self.topo.msg_secs(&self.net, w, bytes)
                };
                let d = self.sim.comm(w, dur, frontier);
                secs += dur;
                next = next.max(d);
            }
            self.bytes_per_worker[s] += bytes * peers;
            sent_total += bytes * peers;
            frontier = next;
        }
        self.stats
            .record(CommKind::SequentialBroadcast, sent_total, sent_total, secs);
        self.note_collective();
        self.wrap((), vec![frontier; n])
    }

    // ---- all-to-all timing core -----------------------------------------

    /// Time a symmetric block exchange from the per-pair byte matrix
    /// (`pair[i][j]` = bytes `i` sends to `j`), per the configured
    /// algorithm. Latency is charged **per actual message**: a peer
    /// exchanged zero bytes with costs nothing (degenerate partitions
    /// with empty slices don't pay phantom latency).
    fn all_to_all(&mut self, pair: &[Vec<usize>], kind: CommKind) -> DoneTimes {
        let n = pair.len();
        if self.trace.is_some() {
            let sent: Vec<usize> = pair.iter().map(|row| row.iter().sum()).collect();
            let recv: Vec<usize> =
                (0..n).map(|w| (0..n).map(|p| pair[p][w]).sum()).collect();
            let (algo, rounds) = match self.all_to_all {
                AllToAllAlgo::Naive => ("naive", Rounds::Burst { msgs: burst_msgs(pair) }),
                AllToAllAlgo::Pairwise if n.is_power_of_two() => {
                    ("pairwise", Rounds::PairRounds { rounds: pairwise_rounds(pair) })
                }
                AllToAllAlgo::Pairwise => {
                    ("pairwise", Rounds::OffsetRounds { rounds: n.saturating_sub(1) })
                }
            };
            for (w, b) in sent.iter().enumerate() {
                self.bytes_per_worker[w] += b;
            }
            let (s, r) = (sent.iter().sum(), recv.iter().sum());
            self.push_post(kind, algo, sent, recv, rounds);
            self.stats.record(kind, s, r, 0.0);
            return vec![0.0; n];
        }
        let ready: Vec<f64> = (0..n).map(|w| self.sim.now(w)).collect();
        let (done, secs) = match self.all_to_all {
            AllToAllAlgo::Naive => self.a2a_naive(pair, &ready),
            AllToAllAlgo::Pairwise => self.a2a_pairwise(pair, &ready),
        };
        // sent from row sums, received from column sums — derived
        // independently so the conservation property (Σ sent == Σ recv)
        // checks the byte matrix, not one accumulator against itself
        let mut sent_total = 0usize;
        let mut recv_total = 0usize;
        for w in 0..n {
            let sent: usize = pair[w].iter().sum();
            let recv: usize = (0..n).map(|p| pair[p][w]).sum();
            self.bytes_per_worker[w] += sent;
            sent_total += sent;
            recv_total += recv;
        }
        self.stats.record(kind, sent_total, recv_total, secs);
        self.note_collective();
        done
    }

    /// One burst per worker: full-duplex NIC occupancy is
    /// `max(sent, received)` wire time plus latency per actual message.
    fn a2a_naive(&mut self, pair: &[Vec<usize>], ready: &[f64]) -> (DoneTimes, f64) {
        let n = pair.len();
        let lat = self.net.latency_us * 1e-6;
        let mut done = vec![0.0; n];
        let mut secs = 0.0;
        for w in 0..n {
            let sent: usize = pair[w].iter().sum();
            let recv: usize = (0..n).map(|p| pair[p][w]).sum();
            let sent_msgs = pair[w].iter().filter(|&&b| b > 0).count();
            let recv_msgs = (0..n).filter(|&p| pair[p][w] > 0).count();
            let wire = self
                .topo
                .wire_secs(&self.net, w, sent)
                .max(self.topo.wire_secs(&self.net, w, recv))
                + lat * sent_msgs.max(recv_msgs) as f64;
            done[w] = self.sim.comm(w, wire, ready[w]);
            secs += wire;
        }
        (done, secs)
    }

    /// `N-1` pairwise-exchange rounds. For power-of-two clusters the
    /// rounds are XOR-paired and pair-synchronized (a straggler NIC
    /// delays its partner each round — the contagion flat bursts hide);
    /// otherwise a round-robin offset schedule without pair coupling.
    fn a2a_pairwise(&mut self, pair: &[Vec<usize>], ready: &[f64]) -> (DoneTimes, f64) {
        let n = pair.len();
        let lat = self.net.latency_us * 1e-6;
        let mut done = ready.to_vec();
        let mut secs = 0.0;
        if n.is_power_of_two() {
            for r in 1..n {
                for w in 0..n {
                    let p = w ^ r;
                    if w > p {
                        continue; // each unordered pair once per round
                    }
                    let exchange = |comm: &Self, a: usize, b: usize| -> f64 {
                        let (s, v) = (pair[a][b], pair[b][a]);
                        if s + v == 0 {
                            return 0.0;
                        }
                        comm.topo
                            .wire_secs(&comm.net, a, s)
                            .max(comm.topo.wire_secs(&comm.net, a, v))
                            + lat
                    };
                    let (dw, dp) = (exchange(self, w, p), exchange(self, p, w));
                    if dw + dp == 0.0 {
                        continue; // nothing exchanged: no round, no sync
                    }
                    let start = done[w].max(done[p]);
                    let tw = self.sim.comm(w, dw, start);
                    let tp = self.sim.comm(p, dp, start);
                    secs += dw + dp;
                    let t = tw.max(tp);
                    done[w] = t;
                    done[p] = t;
                }
            }
        } else {
            for r in 1..n {
                for (w, d) in done.iter_mut().enumerate() {
                    let to = (w + r) % n;
                    let from = (w + n - r) % n;
                    let (s, v) = (pair[w][to], pair[from][w]);
                    if s + v == 0 {
                        continue;
                    }
                    let dur = self
                        .topo
                        .wire_secs(&self.net, w, s)
                        .max(self.topo.wire_secs(&self.net, w, v))
                        + lat;
                    *d = self.sim.comm(w, dur, *d);
                    secs += dur;
                }
            }
        }
        (done, secs)
    }
}

/// The naive algorithm's burst: every real (non-zero) off-diagonal
/// message of the pair matrix.
fn burst_msgs(pair: &[Vec<usize>]) -> Vec<(usize, usize, usize)> {
    let mut msgs = Vec::new();
    for (i, row) in pair.iter().enumerate() {
        for (j, &b) in row.iter().enumerate() {
            if b > 0 {
                msgs.push((i, j, b));
            }
        }
    }
    msgs
}

/// The XOR-paired exchange schedule (mirrors `a2a_pairwise`'s
/// power-of-two path, including its skip of empty exchanges).
fn pairwise_rounds(pair: &[Vec<usize>]) -> Vec<Vec<(usize, usize)>> {
    let n = pair.len();
    let mut rounds = Vec::with_capacity(n.saturating_sub(1));
    for r in 1..n {
        let mut round = Vec::new();
        for w in 0..n {
            let p = w ^ r;
            if w < p && pair[w][p] + pair[p][w] > 0 {
                round.push((w, p));
            }
        }
        rounds.push(round);
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{dim_slices, row_slices};

    fn comm(n: usize) -> Comm {
        Comm::new(n, NetModel::default(), &CommTuning::default()).unwrap()
    }

    fn comm_with(n: usize, tuning: &CommTuning) -> Comm {
        Comm::new(n, NetModel::default(), tuning).unwrap()
    }

    /// split then gather must reproduce the original vertex-sliced data.
    #[test]
    fn split_gather_roundtrip() {
        let (v, d, n) = (12, 10, 4);
        let full = Matrix::from_fn(v, d, |r, c| (r * 100 + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut comm = comm(n);
        let (sliced, _t1) = comm.split(&inputs, &rp, &dp);
        for (j, s) in sliced.iter().enumerate() {
            assert_eq!(*s, full.slice_cols(dp[j].clone()));
        }
        let (back, _t2) = comm.gather(&sliced, &rp, &dp);
        for (i, b) in back.iter().enumerate() {
            assert_eq!(*b, inputs[i]);
        }
    }

    /// Non-divisible shapes: V and D not multiples of N exercise the
    /// `row_slices`/`dim_slices` remainder paths (first slices one wider).
    #[test]
    fn split_gather_roundtrip_non_divisible() {
        for (v, d, n) in [(13usize, 10usize, 4usize), (7, 5, 3), (17, 9, 8), (5, 4, 5)] {
            let full = Matrix::from_fn(v, d, |r, c| (r * 100 + c) as f32);
            let rp = row_slices(v, n);
            let dp = dim_slices(d, n);
            assert_eq!(rp.iter().map(|r| r.len()).sum::<usize>(), v);
            assert_eq!(dp.iter().map(|r| r.len()).sum::<usize>(), d);
            let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
            let mut comm = comm(n);
            let (sliced, _) = comm.split(&inputs, &rp, &dp);
            for (j, s) in sliced.iter().enumerate() {
                assert_eq!(*s, full.slice_cols(dp[j].clone()), "v={v} d={d} n={n} slice {j}");
            }
            let (back, _) = comm.gather(&sliced, &rp, &dp);
            for (i, b) in back.iter().enumerate() {
                assert_eq!(*b, inputs[i], "v={v} d={d} n={n} worker {i}");
            }
        }
    }

    /// Remainder slices differ by at most one row/column, so the
    /// all-to-all volume stays balanced to within one slice row.
    #[test]
    fn non_divisible_comm_nearly_balanced() {
        let (v, d, n) = (1021usize, 61usize, 4usize); // both indivisible by 4
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut comm = comm(n);
        let _ = comm.split(&inputs, &rp, &dp);
        let totals = comm.sim().comm_totals();
        let max = totals.iter().copied().fold(0.0, f64::max);
        let min = totals.iter().copied().fold(f64::MAX, f64::min);
        assert!(max / min < 1.05, "remainder imbalance {max}/{min}");
    }

    #[test]
    fn allgather_places_blocks_by_row_parts() {
        let (v, d, n) = (11usize, 3usize, 3usize);
        let full = Matrix::from_fn(v, d, |r, c| (10 * r + c) as f32);
        let rp = row_slices(v, n);
        let blocks: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut comm = comm(n);
        let (got, done) = comm.allgather_rows(&blocks, &rp);
        assert_eq!(got, full);
        assert!(done.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn split_comm_time_balanced() {
        let (v, d, n) = (1024, 64, 4);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut comm = comm(n);
        let _ = comm.split(&inputs, &rp, &dp);
        let totals = comm.sim().comm_totals();
        let max = totals.iter().copied().fold(0.0, f64::max);
        let min = totals.iter().copied().fold(f64::MAX, f64::min);
        assert!(max / min < 1.001, "TP collectives are perfectly balanced");
    }

    #[test]
    fn allreduce_sums_and_times() {
        let n = 4;
        let inputs: Vec<Matrix> =
            (0..n).map(|i| Matrix::from_fn(3, 3, |_, _| i as f32)).collect();
        let mut comm = comm(n);
        let (sum, done) = comm.allreduce_sum(&inputs);
        assert_eq!(sum.get(0, 0), 0.0 + 1.0 + 2.0 + 3.0);
        assert!(done.iter().all(|&t| t > 0.0));
        assert!(done.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12));
    }

    #[test]
    fn sequential_broadcast_serializes() {
        let n = 4;
        let rows = 256;
        let inputs: Vec<Matrix> = (0..n).map(|_| Matrix::zeros(rows, 64)).collect();
        let rp = row_slices(rows * n, n);
        // sancus-style sequential broadcast strictly slower than allgather
        let mut c1 = comm(n);
        let (_, d1) = c1.sequential_broadcast(&inputs);
        let mut c2 = comm(n);
        let (_, d2) = c2.allgather_rows(&inputs, &rp);
        assert!(d1[0] > d2[0] * 1.5, "seq {} vs allgather {}", d1[0], d2[0]);
    }

    /// The byte-only probe must model the exact schedule of the
    /// data-plane sequential broadcast (the planner scores with it).
    #[test]
    fn sequential_broadcast_bytes_matches_data_plane() {
        let n = 4;
        let inputs: Vec<Matrix> = (0..n).map(|_| Matrix::zeros(128, 32)).collect();
        let bytes: Vec<usize> = inputs.iter().map(Matrix::bytes).collect();
        let mut c1 = comm(n);
        let (_, d1) = c1.sequential_broadcast(&inputs);
        let mut c2 = comm(n);
        let ((), d2) = c2.isequential_broadcast_bytes(&bytes).wait();
        assert_eq!(d1, d2);
        assert_eq!(
            c1.stats().kind(CommKind::SequentialBroadcast),
            c2.stats().kind(CommKind::SequentialBroadcast)
        );
    }

    #[test]
    fn fetch_rows_moves_right_data() {
        let owner_rows = Matrix::from_fn(8, 4, |r, c| (r * 10 + c) as f32);
        let mut comm = comm(2);
        // owner 1 owns global rows 8..16
        let (block, t) = comm.fetch_rows(&owner_rows, 8, &[9, 12], 1, 0);
        assert_eq!(block.row(0), owner_rows.row(1));
        assert_eq!(block.row(1), owner_rows.row(4));
        assert!(t > 0.0);
    }

    #[test]
    fn gather_volume_constant_in_workers() {
        // paper §3.2: TP total communication ~ 2 V D per round, independent
        // of N — check gather totals stay ~flat as N grows
        let (v, d) = (1024, 64);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let mut totals = Vec::new();
        for n in [2usize, 4, 8] {
            let rp = row_slices(v, n);
            let dp = dim_slices(d, n);
            let sliced: Vec<Matrix> =
                dp.iter().map(|dpj| full.slice_cols(dpj.clone())).collect();
            // isolate wire time: latency scales with peer count by design
            let net0 = NetModel { latency_us: 0.0, ..NetModel::default() };
            let mut comm = Comm::new(n, net0, &CommTuning::default()).unwrap();
            let _ = comm.gather(&sliced, &rp, &dp);
            totals.push(comm.sim().comm_totals().iter().sum::<f64>());
        }
        // total wire converges to (N-1)/N * V*D*4/bw: bounded, not linear
        // in N (ratio n=8 : n=2 is exactly 1.75)
        assert!(totals[2] < totals[0] * 1.8, "{totals:?}");
        assert!(totals[2] > totals[1], "monotone but saturating: {totals:?}");
    }

    /// The satellite bugfix: latency is charged per actual message, so a
    /// worker whose slices are empty (degenerate partition) pays nothing,
    /// and partially-degenerate workers pay for their real peer count.
    #[test]
    fn latency_charged_per_actual_message() {
        // v = d = 3 over n = 4: worker 3 owns zero rows AND zero columns
        let (v, d, n) = (3usize, 3usize, 4usize);
        let full = Matrix::from_fn(v, d, |r, c| (r * 10 + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        assert_eq!(rp[3].len(), 0, "test premise: worker 3 has no rows");
        assert_eq!(dp[3].len(), 0, "test premise: worker 3 has no columns");
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        // near-infinite bandwidth isolates the latency term
        let net = NetModel { bandwidth_gbps: 1e12, latency_us: 1e6, ..NetModel::default() };
        let mut comm = Comm::new(n, net, &CommTuning::default()).unwrap();
        let (_, done) = comm.split(&inputs, &rp, &dp);
        let lat = 1.0; // 1e6 us
        // worker 3 exchanges nothing: no messages, no latency
        assert!(done[3] < 1e-6, "idle worker charged {}", done[3]);
        // workers 0..2 send their row to the 2 *other* non-empty dim
        // owners and receive 2 blocks: 2 messages, not n-1 = 3
        for (w, t) in done.iter().enumerate().take(3) {
            assert!(
                (t - 2.0 * lat).abs() < 1e-6,
                "worker {w} charged {t} (want 2 messages)"
            );
        }
    }

    /// All algorithm combinations move bit-identical payloads; only the
    /// modeled times differ.
    #[test]
    fn algorithms_share_the_data_plane() {
        let (v, d, n) = (64usize, 24usize, 4usize);
        let full = Matrix::from_fn(v, d, |r, c| ((r * 13 + c * 7) % 19) as f32 - 9.0);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let grads: Vec<Matrix> =
            (0..n).map(|i| Matrix::from_fn(8, 8, |r, c| (r + c + i) as f32)).collect();
        let mut outs: Vec<(Vec<Matrix>, Matrix)> = Vec::new();
        for a2a in [AllToAllAlgo::Naive, AllToAllAlgo::Pairwise] {
            for ar in [AllReduceAlgo::Ring, AllReduceAlgo::FlatTree] {
                let tuning =
                    CommTuning { all_to_all: a2a, allreduce: ar, ..CommTuning::default() };
                let mut comm = comm_with(n, &tuning);
                let (sliced, _) = comm.split(&inputs, &rp, &dp);
                let (sum, _) = comm.allreduce_sum(&grads);
                outs.push((sliced, sum));
            }
        }
        for (sliced, sum) in &outs[1..] {
            for (a, b) in sliced.iter().zip(&outs[0].0) {
                assert_eq!(a, b, "payload differs across CommAlgo variants");
            }
            assert_eq!(sum, &outs[0].1);
        }
    }

    /// The pairwise fallback for non-power-of-two clusters still moves
    /// the right data and produces monotone, positive done-times.
    #[test]
    fn pairwise_handles_non_power_of_two_clusters() {
        let (v, d, n) = (21usize, 9usize, 3usize);
        let full = Matrix::from_fn(v, d, |r, c| (r * 7 + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let tuning = CommTuning { all_to_all: AllToAllAlgo::Pairwise, ..CommTuning::default() };
        let mut comm = comm_with(n, &tuning);
        let (sliced, done) = comm.split(&inputs, &rp, &dp);
        for (j, s) in sliced.iter().enumerate() {
            assert_eq!(*s, full.slice_cols(dp[j].clone()));
        }
        assert!(done.iter().all(|&t| t > 0.0));
        let (back, done2) = comm.gather(&sliced, &rp, &dp);
        for (i, b) in back.iter().enumerate() {
            assert_eq!(*b, inputs[i]);
        }
        for (a, b) in done.iter().zip(&done2) {
            assert!(b >= a, "time went backwards: {a} -> {b}");
        }
    }

    /// A straggler NIC (per-worker bandwidth multiplier < 1) stretches
    /// the collective's makespan by the slowdown factor.
    #[test]
    fn straggler_topology_slows_the_collective() {
        let (v, d, n) = (512usize, 32usize, 4usize);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let run = |bw_scale: Vec<f64>| -> f64 {
            let tuning = CommTuning { bw_scale, ..CommTuning::default() };
            // zero latency isolates the wire term the topology scales
            let net0 = NetModel { latency_us: 0.0, ..NetModel::default() };
            let mut comm = Comm::new(n, net0, &tuning).unwrap();
            let (_, done) = comm.split(&inputs, &rp, &dp);
            done.iter().copied().fold(0.0, f64::max)
        };
        let flat = run(vec![]);
        let straggler = run(vec![0.25]);
        assert!(straggler > flat * 2.0, "straggler {straggler} vs flat {flat}");
    }

    #[test]
    fn flat_tree_allreduce_slower_than_ring_at_scale() {
        let n = 8;
        let grads: Vec<Matrix> =
            (0..n).map(|_| Matrix::from_fn(64, 64, |r, c| (r + c) as f32)).collect();
        let t = |algo: AllReduceAlgo| -> f64 {
            let tuning = CommTuning { allreduce: algo, ..CommTuning::default() };
            let mut comm = comm_with(n, &tuning);
            let (_, done) = comm.allreduce_sum(&grads);
            // sent-side accounting stays consistent for every algorithm
            assert_eq!(
                comm.bytes_per_worker().iter().sum::<usize>(),
                comm.stats().total_sent(),
                "{algo:?} per-worker bytes disagree with the stats total"
            );
            done[0]
        };
        assert!(
            t(AllReduceAlgo::FlatTree) > t(AllReduceAlgo::Ring),
            "the root bottleneck must show"
        );
    }

    /// `i*` then `wait` is the blocking call: same data, same done-times.
    #[test]
    fn istar_then_wait_equals_blocking() {
        let (v, d, n) = (40usize, 16usize, 4usize);
        let full = Matrix::from_fn(v, d, |r, c| (r * 3 + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut a = comm(n);
        let mut b = comm(n);
        let (da, ta) = a.split(&inputs, &rp, &dp);
        let (db, tb) = b.isplit(&inputs, &rp, &dp).wait();
        assert_eq!(da, db);
        assert_eq!(ta, tb);
        assert_eq!(a.stats(), b.stats());
    }

    /// Posting a collective then scheduling compute must not delay the
    /// posted NIC events — the overlap contract engines rely on.
    #[test]
    fn posted_handle_overlaps_later_compute() {
        let n = 2;
        let rp = row_slices(64, n);
        let dp = dim_slices(16, n);
        let full = Matrix::from_fn(64, 16, |r, c| (r + c) as f32);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut comm = comm(n);
        let handle = comm.isplit(&inputs, &rp, &dp);
        let posted_done = handle.done().clone();
        // heavy compute submitted after the post
        for w in 0..n {
            comm.compute(w, 10.0, 0.0);
        }
        let (_, done) = handle.wait();
        assert_eq!(done, posted_done, "compute after the post delayed the collective");
        assert!(done.iter().all(|&t| t < 1.0), "{done:?}");
        assert_eq!(comm.makespan(), 10.0);
    }

    #[test]
    fn stats_conserve_bytes_and_name_kinds() {
        let (v, d, n) = (32usize, 8usize, 4usize);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut comm = comm(n);
        let (sliced, _) = comm.split(&inputs, &rp, &dp);
        let _ = comm.gather(&sliced, &rp, &dp);
        comm.p2p(0, 1024);
        for kind in [CommKind::Split, CommKind::Gather] {
            let s = comm.stats().kind(kind);
            assert_eq!(s.ops, 1);
            assert_eq!(s.bytes_sent, s.bytes_recv, "{}", kind.name());
            assert!(s.bytes_sent > 0 && s.secs > 0.0);
        }
        assert_eq!(comm.stats().kind(CommKind::PointToPoint).bytes_sent, 1024);
        let names: Vec<&str> = comm.stats().breakdown().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["split", "gather", "p2p"]);
        assert_eq!(
            comm.bytes_per_worker().iter().sum::<usize>(),
            comm.stats().total_sent()
        );
    }

    /// The satellite bugfix: a `bw_scale` list *longer* than the cluster
    /// used to be silently truncated — now it's a config error, while
    /// shorter lists still pad with 1.0.
    #[test]
    fn over_long_bw_scale_is_rejected_not_truncated() {
        let err = Topology::with_bw_scale(4, &[1.0; 5]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("5 entries"), "{msg}");
        assert!(msg.contains("4 workers"), "{msg}");
        let tuning = CommTuning { bw_scale: vec![0.5; 5], ..CommTuning::default() };
        assert!(Comm::new(4, NetModel::default(), &tuning).is_err());
        // padding still works: 1 entry over 4 workers fills with 1.0
        let topo = Topology::with_bw_scale(4, &[0.25]).unwrap();
        assert_eq!(topo.bw_scale(0), 0.25);
        assert_eq!(topo.bw_scale(3), 1.0);
        // and an exact-length list is taken verbatim
        assert!(Topology::with_bw_scale(2, &[0.5, 2.0]).is_ok());
    }

    /// An armed fault fires at the requested collective ordinal with the
    /// sim's makespan at that point; an unarmed comm never reports one.
    #[test]
    fn armed_fault_fires_at_the_requested_collective() {
        let (v, d, n) = (32usize, 8usize, 4usize);
        let full = Matrix::from_fn(v, d, |r, c| (r + c) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let inputs: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        let mut quiet = comm(n);
        let (sliced, _) = quiet.split(&inputs, &rp, &dp);
        let _ = quiet.gather(&sliced, &rp, &dp);
        assert_eq!(quiet.fault_event(), None);

        let mut armed = comm(n);
        armed.arm_fault(2, 2);
        let (sliced, _) = armed.split(&inputs, &rp, &dp);
        assert_eq!(armed.fault_event(), None, "first collective survives");
        let _ = armed.gather(&sliced, &rp, &dp);
        let ev = armed.fault_event().expect("second collective kills");
        assert_eq!(ev.worker, 2);
        assert_eq!(ev.at_collective, 2);
        assert!(ev.at_secs > 0.0);
        assert!(ev.at_secs <= armed.makespan() + 1e-12);
        // the event is recorded once, not re-armed by later collectives
        let _ = armed.allreduce_sum(&inputs);
        assert_eq!(armed.fault_event().map(|e| e.at_collective), Some(2));
    }
}
