//! Micro-bench: collectives data plane + sim accounting (L3 hot path),
//! through the `cluster::Comm` communicator. Hand-rolled harness
//! (criterion is unavailable offline): median of repeated timed runs,
//! printed criterion-style.

use std::time::Instant;

use neutron_tp::cluster::Comm;
use neutron_tp::config::{AllToAllAlgo, CommTuning, NetModel};
use neutron_tp::tensor::{dim_slices, row_slices, Matrix};

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    let med = samples[samples.len() / 2];
    println!("{name:<48} median {:>10.3} us ({iters} iters)", med * 1e6);
}

fn main() {
    let net = NetModel::default();
    println!("# collectives microbench (data plane + event sim, via cluster::Comm)");
    for (v, d, n) in [(8192usize, 64usize, 4usize), (8192, 64, 16), (65536, 128, 16)] {
        let full = Matrix::from_fn(v, d, |r, c| ((r + c) % 17) as f32);
        let rp = row_slices(v, n);
        let dp = dim_slices(d, n);
        let rows: Vec<Matrix> = rp.iter().map(|r| full.slice_rows(r.clone())).collect();
        for a2a in [AllToAllAlgo::Naive, AllToAllAlgo::Pairwise] {
            let tuning = CommTuning { all_to_all: a2a, ..CommTuning::default() };
            bench(&format!("split({})  v={v} d={d} n={n}", a2a.name()), 20, || {
                let mut comm = Comm::new(n, net, &tuning).unwrap();
                let _ = comm.split(&rows, &rp, &dp);
            });
        }
        let slices: Vec<Matrix> = dp.iter().map(|dpj| full.slice_cols(dpj.clone())).collect();
        bench(&format!("gather     v={v} d={d} n={n}"), 20, || {
            let mut comm = Comm::new(n, net, &CommTuning::default()).unwrap();
            let _ = comm.gather(&slices, &rp, &dp);
        });
        let grads: Vec<Matrix> =
            (0..n).map(|_| Matrix::from_fn(256, d, |r, c| (r + c) as f32)).collect();
        bench(&format!("allreduce  256x{d} n={n}"), 50, || {
            let mut comm = Comm::new(n, net, &CommTuning::default()).unwrap();
            let _ = comm.allreduce_sum(&grads);
        });
    }
}
