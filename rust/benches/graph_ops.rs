//! Micro-bench: graph substrate (generation, transpose, chunk planning,
//! partitioners) — the per-epoch L3 setup costs.

use std::time::Instant;

use neutron_tp::graph::chunk::ChunkPlan;
use neutron_tp::graph::{generate, partition};

fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    for _ in 0..2 {
        f();
    }
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    println!(
        "{name:<48} median {:>10.3} ms ({iters} iters)",
        samples[samples.len() / 2] * 1e3
    );
}

fn main() {
    println!("# graph substrate microbench");
    for (v, e) in [(8192usize, 409_600usize), (65536, 1_310_720)] {
        bench(&format!("rmat generate        v={v} e={e}"), 5, || {
            let _ = generate::rmat(v, e, generate::RMAT_SKEWED, 7);
        });
        let g = generate::rmat(v, e, generate::RMAT_SKEWED, 7).gcn_normalized();
        bench(&format!("csr transpose        v={v} e={e}"), 5, || {
            let _ = g.transpose();
        });
        bench(&format!("chunk plan (4 chunks) v={v} e={e}"), 5, || {
            let _ = ChunkPlan::build(&g, v / 4, v / 4, 1 << 20);
        });
        bench(&format!("chunk partition      v={v}"), 10, || {
            let _ = partition::chunk_partition(v, 16);
        });
        bench(&format!("greedy min-cut       v={v} e={e}"), 3, || {
            let _ = partition::greedy_min_cut(&g, 16);
        });
    }
}
