//! Device-path bench: measured artifact execution times for the two
//! aggregation lowerings (scatter vs Pallas-structured CSR) and the fused
//! dense kernel, through the full Rust runtime (executor pool, padding,
//! crop). These are the numbers the event sim schedules (DESIGN.md §4)
//! and the perf baseline for L1/L3 optimization.
//!
//! The final sections measure the batched asynchronous dispatch the
//! engines use (submit all jobs, then wait) against the serial
//! one-`run`-at-a-time loop it replaced, and the CSR row-blocked
//! aggregation kernel against the COO scatter baseline on the largest
//! builtin bucket across intra-job thread teams.

use std::time::Instant;

use neutron_tp::graph::chunk::ChunkPlan;
use neutron_tp::graph::generate;
use neutron_tp::model::params::DenseLayer;
use neutron_tp::runtime::ops::Ops;
use neutron_tp::runtime::{ArtifactStore, ExecutorPool};
use neutron_tp::tensor::Matrix;
use neutron_tp::util::Rng;

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::load("artifacts")?;
    let pool = ExecutorPool::new(&store, 1)?; // single thread: stable medians
    println!("# artifact execution bench (measured device seconds)");

    let mut rng = Rng::seed_from_u64(3);
    for (v, e) in [(1024usize, 8192usize), (8192, 409_600)] {
        let g = generate::rmat(v, e, generate::RMAT_SKEWED, 5).gcn_normalized();
        let x = Matrix::from_fn(v, 32, |_, _| rng.gen_f32_range(-1.0, 1.0));
        for pallas in [false, true] {
            let ops = Ops::new(&store, &pool, pallas);
            // pick the artifact first so the plan uses its exact buckets
            let art = match ops.agg_artifact(v, e.max(4096), v) {
                Ok(a) => a.name.clone(),
                Err(err) => {
                    println!("agg v={v}: {err}");
                    continue;
                }
            };
            let art = store.get(&art).unwrap();
            let c_bucket = art.inputs[0].shape[0] - 1;
            let e_bucket = art.inputs[1].shape[0];
            let plan = ChunkPlan::build(&g, c_bucket.min(v), c_bucket, e_bucket);
            let pass = &plan.chunks[0].passes[0];
            // warmup
            let _ = ops.agg_pass(art, pass, plan.chunks[0].num_rows(), &x)?;
            let samples: Vec<f64> = (0..10)
                .map(|_| ops.agg_pass(art, pass, plan.chunks[0].num_rows(), &x).map(|r| r.1))
                .collect::<Result<_, _>>()?;
            let med = median(samples);
            let live = pass.live_edges as f64;
            println!(
                "agg[{}] v={v} e_bucket={} live={live}: {:.3} ms  ({:.1} Medges/s)",
                if pallas { "pallas" } else { "scatter" },
                e_bucket,
                med * 1e3,
                live / med / 1e6
            );
        }
    }

    // dense path
    let ops = Ops::new(&store, &pool, false);
    for (b, d, h) in [(2048usize, 602usize, 256usize), (4096, 128, 128)] {
        let layer = DenseLayer::glorot(d, h, &mut rng);
        let x = Matrix::from_fn(b, d, |_, _| rng.gen_f32_range(-1.0, 1.0));
        if ops.dense_fwd(&x, &layer.w, &layer.b, true).is_err() {
            println!("dense b={b} d={d} h={h}: no artifact");
            continue;
        }
        let mut wall = Vec::new();
        let mut dev = Vec::new();
        for _ in 0..10 {
            let t0 = Instant::now();
            let (_, _, s) = ops.dense_fwd(&x, &layer.w, &layer.b, true)?;
            wall.push(t0.elapsed().as_secs_f64());
            dev.push(s);
        }
        let flops = 2.0 * b as f64 * d as f64 * h as f64;
        println!(
            "dense_relu b={b} d={d} h={h}: device {:.3} ms, wall {:.3} ms ({:.1} GFLOP/s; \
             L3 overhead {:.0}%)",
            median(dev.clone()) * 1e3,
            median(wall.clone()) * 1e3,
            flops / median(dev.clone()) / 1e9,
            (median(wall) / median(dev) - 1.0) * 100.0
        );
    }

    // batched asynchronous dispatch vs serial run-per-job (the engines'
    // hot-path protocol): N independent dense jobs, wall-clock only
    println!("\n# dispatch: serial run loop vs submit-all-then-wait");
    for threads in [1usize, 2, 4] {
        let apool = ExecutorPool::new(&store, threads)?;
        let aops = Ops::new(&store, &apool, false);
        let layer = DenseLayer::glorot(128, 128, &mut rng);
        let xs: Vec<Matrix> = (0..8)
            .map(|_| Matrix::from_fn(1024, 128, |_, _| rng.gen_f32_range(-1.0, 1.0)))
            .collect();
        // warmup
        for x in &xs {
            let _ = aops.dense_fwd(x, &layer.w, &layer.b, true)?;
        }
        let serial = median(
            (0..5)
                .map(|_| {
                    let t0 = Instant::now();
                    for x in &xs {
                        let _ = aops.dense_fwd(x, &layer.w, &layer.b, true)?;
                    }
                    Ok(t0.elapsed().as_secs_f64())
                })
                .collect::<anyhow::Result<Vec<f64>>>()?,
        );
        let batched = median(
            (0..5)
                .map(|_| {
                    let t0 = Instant::now();
                    let pending: Vec<_> = xs
                        .iter()
                        .map(|x| aops.submit_dense_fwd(x, &layer.w, &layer.b, true))
                        .collect::<anyhow::Result<_>>()?;
                    for p in pending {
                        let _ = p.wait()?;
                    }
                    Ok(t0.elapsed().as_secs_f64())
                })
                .collect::<anyhow::Result<Vec<f64>>>()?,
        );
        println!(
            "threads={threads}: serial {:.3} ms, batched {:.3} ms ({:.2}x)",
            serial * 1e3,
            batched * 1e3,
            serial / batched.max(1e-12)
        );
    }
    // CSR row-blocked kernel vs the COO scatter baseline on the LARGEST
    // builtin bucket (fs-scale: s=65536, c=65536, e=2^21), across
    // intra-job thread teams. Acceptance: csr@intra=4 beats scatter.
    println!("\n# aggregation: COO scatter vs CSR row-blocked (largest builtin bucket)");
    {
        let (v, e) = (65_536usize, 2_621_440usize);
        let g = generate::rmat(v, e, generate::RMAT_SKEWED, 11).gcn_normalized();
        let x = Matrix::from_fn(v, 32, |_, _| rng.gen_f32_range(-1.0, 1.0));
        let mut scatter_ms = f64::NAN;
        let mut csr4_ms = f64::NAN;
        for (pallas, intra) in [(false, 1usize), (true, 1), (true, 2), (true, 4)] {
            let pool = ExecutorPool::with_intra(&store, 1, intra)?;
            let ops = Ops::new(&store, &pool, pallas);
            let art = ops.agg_artifact(v - 1, e, v)?;
            let c_bucket = art.inputs[0].shape[0] - 1;
            let e_bucket = art.inputs[1].shape[0];
            let plan = ChunkPlan::build(&g, c_bucket.min(v), c_bucket, e_bucket);
            let pass = &plan.chunks[0].passes[0];
            let rows = plan.chunks[0].num_rows();
            let _ = ops.agg_pass(art, pass, rows, &x)?; // warmup + layout cache
            let med = median(
                (0..5)
                    .map(|_| ops.agg_pass(art, pass, rows, &x).map(|r| r.1))
                    .collect::<Result<Vec<f64>, _>>()?,
            );
            let name = if pallas { "csr_blocked" } else { "scatter" };
            println!(
                "agg[{name}] intra={intra} e_bucket={e_bucket} live={}: {:.3} ms ({:.1} Medges/s)",
                pass.live_edges,
                med * 1e3,
                pass.live_edges as f64 / med / 1e6
            );
            if !pallas {
                scatter_ms = med * 1e3;
            } else if intra == 4 {
                csr4_ms = med * 1e3;
            }
        }
        println!(
            "csr_blocked@4 vs scatter: {:.2}x {}",
            scatter_ms / csr4_ms.max(1e-12),
            if csr4_ms < scatter_ms { "(CSR wins)" } else { "(scatter wins?!)" }
        );
    }

    println!("total artifact executions: {}", pool.executed());
    Ok(())
}
